//! **Parallel macro-tile execution layer**: the locality-tiled kernels
//! sharded across a scoped worker pool (`util::pool::Pool::run_parallel`).
//!
//! PR 1 applied the paper's blocking guidelines per core; this layer
//! distributes the resulting macro-tiles across cores with
//! private-cache-aware partitioning — the step both the PIM training
//! study (Gómez-Luna et al., 2022) and the traditional-ML
//! characterization work (Kumar & Govindarajan, 2024) identify as
//! necessary before locality-tiled kernels reach hardware limits.
//!
//! # Partitioning scheme (deterministic)
//!
//! Work is split on **macro-tile boundaries** so each worker's inner
//! loops see exactly the tile shapes the cache model sized:
//!
//! * **matmul** (plain / bias / transpose-acc) — `MC`-row macro-tile
//!   blocks of the output (refined toward `m / threads` rows when the
//!   matrix has fewer macro-tiles than workers, so e.g. a single-tile
//!   512-row matmul still shards); each worker owns a disjoint `&mut`
//!   row range of `C`, so no synchronisation is needed and per-element
//!   accumulation order is unchanged → bit-identical to the sequential
//!   kernels at ANY thread count.
//! * **pairwise distances** — query tiles (`TileConfig::pair_tiles`);
//!   each worker fills a disjoint block of whole output rows →
//!   bit-identical at any thread count.
//! * **coupled LR+SVM** — one raw [`CoupledPartial`] per
//!   `coupled_rows()` macro-tile of the design matrix, reduced in
//!   **tile-index order** and finalised once. Because the partials are
//!   per macro-tile (never per worker range), the reduction is a pure
//!   function of `(batch, tile config)`: the result is bit-identical at
//!   every thread count and under both schedules. It reassociates the
//!   f32 gradient sums relative to the single-pass sequential kernel,
//!   so multi-tile batches differ from [`coupled_step_tiled`] in the
//!   last bits (≤ 1e-4 vs the naive oracle, property-tested);
//!   single-macro-tile batches short-circuit to the sequential kernel
//!   and are exact.
//!
//! # Scheduling policy (work stealing for skewed shapes)
//!
//! [`Schedule`] selects how macro-tiles are assigned to workers:
//!
//! * [`Schedule::Static`] — the PR-2 scheme: `partition_units` hands
//!   each worker one contiguous range up front. Zero coordination, but
//!   ragged tails, skewed CV splits and heterogeneous per-tile costs
//!   serialise onto the slowest shard.
//! * [`Schedule::Stealing`] — macro-tiles are grouped into fixed-size
//!   chunks (`steal_chunk`: ~4 chunks per worker, so claiming stays
//!   cheap while leaving slack to rebalance) and workers claim the next
//!   unclaimed chunk from a shared atomic cursor
//!   ([`Pool::run_stealing`]). Chunk boundaries are deterministic and
//!   results are merged in chunk order, so **which worker computes a
//!   tile never changes the output**: row-disjoint kernels are
//!   bit-identical to static by row independence, and reductions are
//!   bit-identical because partials are merged by tile index, not
//!   completion order.
//! * [`Schedule::Auto`] — stealing when there are more macro-tiles than
//!   workers (slack to rebalance), static otherwise. Since both
//!   schedules produce identical bits, `Auto` is purely a performance
//!   choice.
//!
//! `partition_units` (static) and `chunk_ranges` (stealing) are the two
//! sources of truth for the scheme; property tests assert each covers
//! every macro-tile exactly once across ragged shapes (no gaps, no
//! overlaps), and the parity suite asserts stealing == static ==
//! sequential bit-for-bit at 1/2/4/7 threads over skewed shapes.
//!
//! # Thread-count and schedule resolution
//!
//! `threads = 1` spawns nothing: the row-disjoint kernels and scans
//! short-circuit to the PR-1 sequential kernels bit-for-bit, and the
//! coupled step runs its per-tile reduction inline — the same bits as
//! every other thread count (but, for multi-tile batches, not the
//! single-pass PR-1 kernel's bits; see the coupled bullet above).
//! [`default_threads`] resolves the session's thread count:
//! `--threads N` override (via [`set_threads`]) → `LOCALITY_ML_THREADS`
//! env var (the CI matrix axis) → `std::thread::available_parallelism`.
//! [`default_schedule`] mirrors it for the scheduling policy:
//! `--schedule` override (via [`set_schedule`]) →
//! `LOCALITY_ML_SCHEDULE` → [`Schedule::Auto`].
//! Per-worker tile sizes come from [`TileConfig::for_workers`], which
//! caps each worker's streamed block to its share of the shared L3 so
//! concurrent working sets don't thrash each other.
//!
//! # The `ExecPolicy` API
//!
//! Every public kernel in this layer now takes one
//! [`&ExecPolicy`](ExecPolicy) — the `*_exec` functions — instead of a
//! hand-threaded `(threads, schedule[, algo])` tuple. The policy is
//! [`resolved`](ExecPolicy::resolve) once per call (so `threads = 0`
//! and `Schedule::Auto` pick up the session overrides) and its thread
//! count is then used **verbatim**, exactly as the tuple signatures
//! did: work-size gating stays a call-site concern
//! ([`ExecPolicy::threads_for`]), so tests and benches can still shard
//! tiny shapes on purpose. The old bare `(threads, schedule[, algo])`
//! tuple signatures are gone: every caller — the parity suites
//! included — goes through the `*_exec` spellings, with pinned-axis
//! policies standing in where a test needs an explicit grid point.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::coupled::{
    coupled_accumulate, coupled_finalize, coupled_step_tiled,
    CoupledPartial,
};
use super::distance::{
    gather_rows, pairwise_sq_dists_gemm_packed, pairwise_sq_dists_tiled,
    transpose_rows, DistanceAlgo, NormCache,
};
use super::matmul::{
    matmul_acc_tiled, matmul_bias_prepacked, matmul_tn_acc_rows,
    matmul_tn_acc_tiled,
};
use super::pack::PackedPanel;
use super::policy::ExecPolicy;
use super::tile::TileConfig;
use crate::util::pool::Pool;

/// Session-wide `--threads` override; 0 = unset.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install the `--threads N` CLI override for the rest of the process
/// (`0` clears it).
pub fn set_threads(threads: usize) {
    THREAD_OVERRIDE.store(threads, Ordering::Relaxed);
}

/// How macro-tile work is assigned to workers. Both schedules produce
/// **identical output bits** (see the module docs); the choice only
/// moves wall-clock on skewed shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// One contiguous macro-tile range per worker, fixed up front.
    Static,
    /// Workers claim fixed-size macro-tile chunks from a shared atomic
    /// cursor; a worker that finishes early steals the next chunk.
    Stealing,
    /// Stealing when there are more macro-tiles than workers (slack to
    /// rebalance), static otherwise.
    Auto,
}

impl Schedule {
    /// Parse a CLI/env spelling. Accepts `static`, `stealing` (or
    /// `steal`), and `auto`, case-insensitively.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "static" => Some(Self::Static),
            "stealing" | "steal" => Some(Self::Stealing),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    /// Canonical spelling (the one `parse` round-trips).
    pub fn name(self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Stealing => "stealing",
            Self::Auto => "auto",
        }
    }
}

/// Session-wide `--schedule` override; 0 = unset, then 1/2/3 for
/// static/stealing/auto (the encoding is private to this pair of fns).
static SCHEDULE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install the `--schedule` CLI override for the rest of the process
/// (`None` clears it).
pub fn set_schedule(schedule: Option<Schedule>) {
    let code = match schedule {
        None => 0,
        Some(Schedule::Static) => 1,
        Some(Schedule::Stealing) => 2,
        Some(Schedule::Auto) => 3,
    };
    SCHEDULE_OVERRIDE.store(code, Ordering::Relaxed);
}

/// Resolve the session scheduling policy: CLI override
/// ([`set_schedule`]) → `LOCALITY_ML_SCHEDULE` (the CI matrix axis;
/// unparsable values are ignored, mirroring the threads policy) →
/// [`Schedule::Auto`].
pub fn default_schedule() -> Schedule {
    match SCHEDULE_OVERRIDE.load(Ordering::Relaxed) {
        1 => return Schedule::Static,
        2 => return Schedule::Stealing,
        3 => return Schedule::Auto,
        _ => {}
    }
    if let Ok(v) = std::env::var("LOCALITY_ML_SCHEDULE") {
        if let Some(s) = Schedule::parse(&v) {
            return s;
        }
    }
    Schedule::Auto
}

/// Whether this call should run the stealing executor: explicit
/// policies are taken verbatim; `Auto` steals only when there are more
/// macro-tile units than workers (otherwise every worker already owns
/// at most one unit and there is nothing to rebalance).
pub(crate) fn use_stealing(
    schedule: Schedule,
    units: usize,
    workers: usize,
) -> bool {
    match schedule {
        Schedule::Static => false,
        Schedule::Stealing => true,
        Schedule::Auto => units > workers,
    }
}

/// Macro-tile units per stolen chunk: ~4 chunks per worker bounds the
/// atomic-cursor traffic while leaving enough slack to rebalance a
/// skewed tail; never below one unit. A pure function of
/// `(units, workers)`, so chunk boundaries — and therefore merge order
/// — are deterministic.
pub(crate) fn steal_chunk(units: usize, workers: usize) -> usize {
    (units / (workers.max(1) * 4)).max(1)
}

/// Contiguous ranges of `chunk` units each (last one ragged) — the
/// stealing counterpart of [`partition_units`]; exactly-once coverage
/// is property-tested alongside it.
pub(crate) fn chunk_ranges(units: usize, chunk: usize) -> Vec<Range<usize>> {
    let chunk = chunk.max(1);
    (0..units.div_ceil(chunk))
        .map(|c| c * chunk..((c + 1) * chunk).min(units))
        .collect()
}

/// The scheduling-policy core shared by every macro-tile fan-out:
/// decide whether this call steals and build the matching deterministic
/// partition — contiguous per-worker ranges for static,
/// `steal_chunk`-sized ranges for stealing. Flattened in order, both
/// partitions enumerate units `0..units` exactly once, which is what
/// keeps outputs schedule-independent. Tweaks to the policy (the `Auto`
/// rule, chunk sizing) belong here, not at the call sites.
pub(crate) fn schedule_parts(
    units: usize,
    threads: usize,
    schedule: Schedule,
) -> (bool, Vec<Range<usize>>) {
    let stealing = use_stealing(schedule, units, threads);
    let parts = if stealing {
        chunk_ranges(units, steal_chunk(units, threads))
    } else {
        partition_units(units, threads)
    };
    (stealing, parts)
}

/// Run boxed jobs under the scheduling policy when the jobs themselves
/// are the macro units (one per CV split, one per learner consumer):
/// stealing claims job indices from the shared cursor, static chunks
/// them contiguously per worker. Results come back in job order either
/// way, so callers' index-ordered merges see identical sequences.
pub(crate) fn run_jobs<'env, T: Send>(
    threads: usize,
    schedule: Schedule,
    jobs: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
) -> Vec<T> {
    if threads > 1 && use_stealing(schedule, jobs.len(), threads) {
        Pool::run_stealing(threads, jobs)
    } else {
        Pool::run_parallel(threads, jobs)
    }
}

/// Minimum kernel work (f32 multiply-adds) before fanning out pays for
/// the scoped spawn/join (~tens of µs for a handful of workers): below
/// this, the sequential kernel wins and the rewired hot paths stay on
/// it. The parallel kernels themselves take `threads` verbatim — this
/// policy lives at the call sites via [`effective_threads`], so tests
/// and benches can still shard tiny shapes on purpose.
pub const MIN_PAR_WORK: usize = 1 << 21;

/// The thread count a rewired hot path should actually use for a kernel
/// invocation of `work` multiply-adds: `threads` when the work clears
/// [`MIN_PAR_WORK`], else 1 (the exact sequential kernel, no spawns).
pub fn effective_threads(threads: usize, work: usize) -> usize {
    if work >= MIN_PAR_WORK {
        threads
    } else {
        1
    }
}

/// Resolve the session thread count: CLI override (`set_threads`) →
/// `LOCALITY_ML_THREADS` → available parallelism → 1.
pub fn default_threads() -> usize {
    let over = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if over > 0 {
        return over;
    }
    if let Ok(v) = std::env::var("LOCALITY_ML_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Deterministic contiguous partition of `units` macro-tile indices
/// into at most `workers` non-empty ranges (earlier ranges get the
/// remainder). This is the one partitioning function every parallel
/// kernel uses; its exactly-once coverage is property-tested.
pub fn partition_units(units: usize, workers: usize) -> Vec<Range<usize>> {
    if units == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(units);
    let base = units / workers;
    let extra = units % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, units);
    out
}

/// Effective shard unit: macro-tile rows (`MC` for matmul, the query
/// tile for distances), refined toward `total / threads` rows when the
/// output has fewer macro-tiles than workers — a 512-row matmul
/// (exactly one Westmere `MC` block, and the CI gate shape) or a
/// low-dimensional scan whose query tile clamps at 512 rows must still
/// shard across every worker. Sharding below the macro-tile only
/// *shrinks* each worker's block (the worker re-tiles internally), so
/// the cache budgets still hold, and the bit-identity of the
/// output-disjoint kernels is row-wise — it never depended on tile
/// alignment. Still a pure function of `(macro_rows, total, threads)`.
pub(crate) fn shard_unit(macro_rows: usize, total: usize,
                         threads: usize) -> usize {
    macro_rows.max(1).min((total / threads.max(1)).max(1))
}

/// Shared row-block fan-out used by every output-disjoint parallel
/// kernel: `out` holds `total` rows of `row_width` f32s, partitioned on
/// `unit`-row macro-tile boundaries across up to `threads` workers;
/// each worker gets `work(lo, hi, block)` with its global row range and
/// the matching disjoint `&mut` block. Under [`Schedule::Static`] the
/// blocks are one contiguous range per worker; under stealing they are
/// [`steal_chunk`]-sized and claimed dynamically — per-row bits never
/// depend on which call computes them, so both produce identical
/// output. Returns `false` (touching nothing) when the partition
/// degenerates to a single range — the caller then runs its sequential
/// kernel, keeping `threads = 1` bit-identical to PR 1.
fn fan_out_rows(
    out: &mut [f32],
    total: usize,
    row_width: usize,
    unit: usize,
    threads: usize,
    schedule: Schedule,
    work: impl Fn(usize, usize, &mut [f32]) + Sync,
) -> bool {
    let unit = unit.max(1);
    let units = total.div_ceil(unit);
    if threads <= 1 || units <= 1 {
        return false;
    }
    let (stealing, parts) = schedule_parts(units, threads, schedule);
    if parts.len() <= 1 {
        return false;
    }
    let work = &work;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(parts.len());
    let mut rest: &mut [f32] = out;
    let mut row0 = 0usize;
    for part in &parts {
        let hi = (part.end * unit).min(total);
        let rows = hi - row0;
        let (head, tail) =
            std::mem::take(&mut rest).split_at_mut(rows * row_width);
        rest = tail;
        let lo = row0;
        jobs.push(Box::new(move || work(lo, hi, head)));
        row0 = hi;
    }
    if stealing {
        Pool::run_stealing(threads, jobs);
    } else {
        Pool::run_parallel(jobs.len(), jobs);
    }
    true
}

/// Core for `C += A·B`: `MC`-row macro-tile blocks of the output fan
/// out across workers, each owning a disjoint `&mut` slice of `C`.
/// Bit-identical to [`matmul_acc_tiled`] at any thread count and under
/// either schedule (row results are independent; per-element
/// accumulation order unchanged).
#[allow(clippy::too_many_arguments)]
fn matmul_acc_core(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let tiles = *t;
    let unit = shard_unit(t.mc, m, threads);
    let ran = fan_out_rows(c, m, n, unit, threads, schedule,
                           |lo, hi, block| {
        matmul_acc_tiled(&a[lo * k..hi * k], b, block, hi - lo, k, n,
                         &tiles);
    });
    if !ran {
        matmul_acc_tiled(a, b, c, m, k, n, t);
    }
}

/// `C = A·B` under an [`ExecPolicy`]: zero then accumulate (mirrors
/// `matmul_tiled`). The policy is resolved once; its thread count is
/// used verbatim (gate with [`ExecPolicy::threads_for`] at the call
/// site if the shape may be tiny).
#[allow(clippy::too_many_arguments)]
pub fn matmul_exec(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    matmul_acc_exec(a, b, c, m, k, n, t, policy);
}

/// `C += A·B` under an [`ExecPolicy`]. Bit-identical to
/// [`matmul_acc_tiled`] under every policy.
#[allow(clippy::too_many_arguments)]
pub fn matmul_acc_exec(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    let p = policy.resolve();
    matmul_acc_core(a, b, c, m, k, n, t, p.threads, p.schedule);
}

/// `C = bias ⊕ A·B` under an [`ExecPolicy`] (mirrors
/// `matmul_bias_tiled`).
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_exec(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    assert_eq!(bias.len(), n);
    assert_eq!(c.len(), m * n);
    for row in c.chunks_exact_mut(n.max(1)) {
        row.copy_from_slice(bias);
    }
    matmul_acc_exec(a, b, c, m, k, n, t, policy);
}

/// `C = bias ⊕ A·B` against a [`PackedPanel`] of `B`, under an
/// [`ExecPolicy`]: the pack is built **once** (at fit time for
/// [`NativeMlp`](crate::learners::NativeMlp) weights) and shared
/// read-only across the row fan-out — each worker streams the same
/// reuse-ordered panels through the SIMD micro-kernel into its disjoint
/// `&mut` rows of `C`. Packed-matmul bits are independent of the row
/// split and of every blocking parameter, so this is bit-identical to
/// the sequential [`matmul_bias_prepacked`] (and to the
/// naive-chain reference) under every policy.
pub fn matmul_bias_prepacked_exec(
    a: &[f32],
    pb: &PackedPanel,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    let (k, n) = (pb.k(), pb.n());
    assert_eq!(a.len(), m * k);
    assert_eq!(bias.len(), n);
    assert_eq!(c.len(), m * n);
    let p = policy.resolve();
    let tiles = *t;
    let unit = shard_unit(t.mc, m, p.threads);
    let ran = fan_out_rows(c, m, n, unit, p.threads, p.schedule,
                           |lo, hi, block| {
        matmul_bias_prepacked(&a[lo * k..hi * k], pb, bias, block,
                              hi - lo, &tiles);
    });
    if !ran {
        matmul_bias_prepacked(a, pb, bias, c, m, t);
    }
}

/// Core for `C += Aᵀ·B` (`a` stored `[k×m]`): row ranges of the output
/// fan out across workers via the row-range core. Per-element
/// accumulation is `p`-ascending regardless of where the row split
/// falls, so results match the sequential kernel bit for bit at any
/// thread count and under either schedule.
#[allow(clippy::too_many_arguments)]
fn matmul_tn_acc_core(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let tiles = *t;
    let unit = shard_unit(t.mc, m, threads);
    let ran = fan_out_rows(c, m, n, unit, threads, schedule,
                           |lo, hi, block| {
        matmul_tn_acc_rows(a, b, block, k, m, n, &tiles, lo, hi);
    });
    if !ran {
        matmul_tn_acc_tiled(a, b, c, k, m, n, t);
    }
}

/// `C += Aᵀ·B` under an [`ExecPolicy`] (`a` stored `[k×m]`).
/// Bit-identical to [`matmul_tn_acc_tiled`] under every policy.
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn_acc_exec(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    let p = policy.resolve();
    matmul_tn_acc_core(a, b, c, k, m, n, t, p.threads, p.schedule);
}

/// Core for Exact parallel pairwise squared distances: query-tile
/// blocks fan out, each worker filling a disjoint block of whole output
/// rows. Bit-identical to [`pairwise_sq_dists_tiled`] at any thread
/// count and under either schedule.
fn dists_tiled_core(
    train: &[f32],
    queries: &[f32],
    d: usize,
    out: &mut [f32],
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(out.len(), nq * n);
    let (qt, _) = t.pair_tiles(d);
    let unit = shard_unit(qt, nq, threads);
    let tiles = *t;
    let ran = fan_out_rows(out, nq, n, unit, threads, schedule,
                           |lo, hi, block| {
        pairwise_sq_dists_tiled(train, &queries[lo * d..hi * d], d,
                                block, &tiles);
    });
    if !ran {
        pairwise_sq_dists_tiled(train, queries, d, out, t);
    }
}

/// Core for GEMM-formulation parallel pairwise distances
/// (`‖q‖² + ‖t‖² − 2·q·t`, clamped ≥ 0): the train matrix is
/// transposed and **packed once** on the calling thread into a
/// [`PackedPanel`] (reuse-ordered, 32-byte-aligned panels), then
/// query-row blocks fan out exactly like the Exact core, every worker
/// streaming the *same* read-only pack through the SIMD micro-kernel
/// into its disjoint `&mut` block of whole output rows. Packed-matmul
/// bits are independent of blocking and of the row split, so the
/// result is bit-identical to the sequential
/// [`pairwise_sq_dists_gemm`](super::distance::pairwise_sq_dists_gemm)
/// at any thread count and under either schedule — and within ≤ 1e-4
/// of the Exact kernels on well-scaled finite data (property-tested).
#[allow(clippy::too_many_arguments)]
fn dists_gemm_core(
    train: &[f32],
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(train_norms.len(), n);
    assert_eq!(query_norms.len(), nq);
    assert_eq!(out.len(), nq * n);
    let train_t = transpose_rows(train, d);
    let pb = PackedPanel::pack(&train_t, d, n, t.kc);
    let pbr = &pb;
    let (qt, _) = t.pair_tiles(d);
    let unit = shard_unit(qt, nq, threads);
    let tiles = *t;
    let ran = fan_out_rows(out, nq, n, unit, threads, schedule,
                           |lo, hi, block| {
        pairwise_sq_dists_gemm_packed(pbr, &queries[lo * d..hi * d], d,
                                      train_norms, &query_norms[lo..hi],
                                      block, &tiles);
    });
    if !ran {
        pairwise_sq_dists_gemm_packed(pbr, queries, d, train_norms,
                                      query_norms, out, t);
    }
}

/// GEMM-formulation parallel pairwise distances under an
/// [`ExecPolicy`] (formulation pinned to Gemm; see
/// [`pairwise_sq_dists_exec`] for the dispatching entry point).
#[allow(clippy::too_many_arguments)]
pub fn pairwise_sq_dists_gemm_exec(
    train: &[f32],
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    let p = policy.resolve();
    dists_gemm_core(train, queries, d, train_norms, query_norms, out, t,
                    p.threads, p.schedule);
}

/// THE parallel distance entry point: one [`ExecPolicy`] decides
/// worker count, schedule, *and* formulation. The policy's algo is
/// resolved **once** on this call's total multiply-adds (so a fan-out
/// can never split one logical pass across formulations), then the
/// Exact tiled fan-out or the packed Gemm fan-out runs. The norm
/// slices are only read on the Gemm path (pass empty slices when the
/// policy is pinned Exact).
#[allow(clippy::too_many_arguments)]
pub fn pairwise_sq_dists_exec(
    train: &[f32],
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
    policy: &ExecPolicy,
) {
    assert!(d > 0, "feature dimension must be positive");
    let n = train.len() / d;
    let nq = queries.len() / d;
    let p = policy.resolve();
    match p.algo.resolve(nq * n * d) {
        DistanceAlgo::Gemm => dists_gemm_core(
            train, queries, d, train_norms, query_norms, out, t,
            p.threads, p.schedule),
        _ => dists_tiled_core(train, queries, d, out, t, p.threads,
                              p.schedule),
    }
}

/// Core for the index-sliced, formulation-dispatching parallel
/// distances — the batched engine behind the §4.1.1 hyperparameter
/// sweep. Under the Gemm formulation the row norms are **gathered from
/// the dataset-level [`NormCache`]** (built once per dataset, reused
/// across every CV split and every sweep candidate), never recomputed
/// per split — the redundancy the paper's "reuse of computation
/// results" guideline removes.
#[allow(clippy::too_many_arguments)]
fn dists_gather_core(
    features: &[f32],
    d: usize,
    train_idx: &[usize],
    query_idx: &[usize],
    cache: &NormCache,
    algo: DistanceAlgo,
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) -> Vec<f32> {
    let train = gather_rows(features, d, train_idx);
    let queries = gather_rows(features, d, query_idx);
    let mut out = vec![0.0f32; query_idx.len() * train_idx.len()];
    match algo.resolve(query_idx.len() * train_idx.len() * d) {
        DistanceAlgo::Gemm => {
            let tn = cache.gather(train_idx);
            let qn = cache.gather(query_idx);
            dists_gemm_core(&train, &queries, d, &tn, &qn, &mut out, t,
                            threads, schedule);
        }
        _ => dists_tiled_core(&train, &queries, d, &mut out, t, threads,
                              schedule),
    }
    out
}

/// Index-sliced parallel distances under an [`ExecPolicy`]: gathers
/// the `train_idx`/`query_idx` rows of one row-major feature matrix
/// and returns the full `|queries| × |train|` distance matrix, with
/// worker count, schedule, and formulation all carried by the policy
/// (norms come from the dataset-level [`NormCache`] on the Gemm path).
pub fn pairwise_sq_dists_gather_exec(
    features: &[f32],
    d: usize,
    train_idx: &[usize],
    query_idx: &[usize],
    cache: &NormCache,
    t: &TileConfig,
    policy: &ExecPolicy,
) -> Vec<f32> {
    let p = policy.resolve();
    dists_gather_core(features, d, train_idx, query_idx, cache, p.algo,
                      t, p.threads, p.schedule)
}

/// Core for the parallel fused coupled LR+SVM step: one raw
/// [`CoupledPartial`] per `coupled_rows()` macro-tile of the design
/// matrix, reduced in **tile-index order** and finalised once over the
/// full batch size. The partial boundaries depend only on
/// `(batch, tile config)` — never on the thread count or on which
/// worker computed a tile — so the result is bit-identical at every
/// thread count and under both schedules; a single-macro-tile batch
/// short-circuits to (and is exactly) the sequential
/// [`coupled_step_tiled`].
#[allow(clippy::too_many_arguments)]
fn coupled_step_core(
    w_lr: &[f32],
    w_svm: &[f32],
    x: &[f32],
    y: &[f32],
    lr: f32,
    lam: f32,
    t: &TileConfig,
    threads: usize,
    schedule: Schedule,
) -> ((Vec<f32>, f32), (Vec<f32>, f32)) {
    let d = w_lr.len();
    assert_eq!(w_svm.len(), d);
    let b = y.len();
    assert_eq!(x.len(), b * d);
    let unit = t.coupled_rows().max(1);
    let units = b.div_ceil(unit);
    if units <= 1 {
        return coupled_step_tiled(w_lr, w_svm, x, y, lr, lam, t);
    }
    let tiles = *t;
    let accumulate_range = |range: Range<usize>| -> Vec<CoupledPartial> {
        range
            .map(|u| {
                let lo = u * unit;
                let hi = ((u + 1) * unit).min(b);
                coupled_accumulate(w_lr, w_svm, &x[lo * d..hi * d],
                                   &y[lo..hi], &tiles)
            })
            .collect()
    };
    let partials: Vec<CoupledPartial> = if threads <= 1 {
        accumulate_range(0..units)
    } else {
        let (stealing, parts) = schedule_parts(units, threads, schedule);
        let acc = &accumulate_range;
        let jobs: Vec<Box<dyn FnOnce() -> Vec<CoupledPartial> + Send + '_>> =
            parts
                .iter()
                .map(|part| {
                    let part = part.clone();
                    Box::new(move || acc(part))
                        as Box<dyn FnOnce() -> Vec<CoupledPartial>
                               + Send + '_>
                })
                .collect();
        let nested = if stealing {
            Pool::run_stealing(threads, jobs)
        } else {
            Pool::run_parallel(jobs.len(), jobs)
        };
        nested.into_iter().flatten().collect()
    };
    let total = reduce_partials(partials, d);
    coupled_finalize(w_lr, w_svm, total, b, lr, lam)
}

/// Parallel fused coupled LR+SVM step under an [`ExecPolicy`].
/// Bit-identical to [`coupled_step_tiled`] under every policy.
pub fn coupled_step_exec(
    w_lr: &[f32],
    w_svm: &[f32],
    x: &[f32],
    y: &[f32],
    lr: f32,
    lam: f32,
    t: &TileConfig,
    policy: &ExecPolicy,
) -> ((Vec<f32>, f32), (Vec<f32>, f32)) {
    let p = policy.resolve();
    coupled_step_core(w_lr, w_svm, x, y, lr, lam, t, p.threads,
                      p.schedule)
}

/// Reduce per-macro-tile partials in tile-index order (the
/// deterministic half of the coupled kernel's parallel contract).
pub(crate) fn reduce_partials(
    partials: Vec<CoupledPartial>,
    d: usize,
) -> CoupledPartial {
    let mut acc = CoupledPartial {
        g_lr: vec![0.0f32; d],
        g_svm: vec![0.0f32; d],
        loss_lr: 0.0,
        loss_svm: 0.0,
    };
    for p in partials {
        for f in 0..d {
            acc.g_lr[f] += p.g_lr[f];
            acc.g_svm[f] += p.g_svm[f];
        }
        acc.loss_lr += p.loss_lr;
        acc.loss_svm += p.loss_svm;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::distance::{
        pairwise_sq_dists_gemm, pairwise_sq_dists_naive, row_sq_norms,
    };
    use crate::kernels::matmul::{
        matmul_bias_prepacked, matmul_bias_tiled, matmul_naive,
        matmul_tiled,
    };
    use crate::kernels::pack::set_force_scalar;
    use crate::learners::linear;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};

    fn rand_tiles(g: &mut Gen) -> TileConfig {
        TileConfig {
            mc: g.usize_in(1, 17),
            kc: g.usize_in(1, 17),
            nc: g.usize_in(1, 17),
            l1_f32: 1 << g.usize_in(6, 10),
        }
    }

    /// A grid point with the thread and schedule axes pinned: the suite
    /// sweeps the exact (threads, schedule) lattice the old tuple
    /// spellings enumerated, through the one public `*_exec` surface.
    fn pinned(threads: usize, sched: Schedule) -> ExecPolicy {
        ExecPolicy::auto().with_threads(threads).with_schedule(sched)
    }

    #[test]
    fn partitions_cover_every_unit_exactly_once() {
        // The satellite invariant: no macro-tile is dropped or computed
        // twice, for ANY (units, workers) combination.
        check("partition-coverage", 120, |g| {
            let units = g.usize_in(0, 500);
            let workers = g.usize_in(1, 33);
            let parts = partition_units(units, workers);
            let mut prev_end = 0;
            for p in &parts {
                prop_assert!(p.start == prev_end,
                    "gap or overlap before {p:?} (prev end {prev_end})");
                prop_assert!(p.end > p.start, "empty range {p:?}");
                prev_end = p.end;
            }
            prop_assert!(prev_end == units,
                "tail units uncovered: {prev_end}/{units}");
            prop_assert!(parts.len() <= workers,
                "{} ranges for {workers} workers", parts.len());
            Ok(())
        });
    }

    #[test]
    fn chunk_ranges_cover_every_unit_exactly_once() {
        // The stealing partition must satisfy the same exactly-once
        // invariant as the static one, ragged last chunk included.
        check("chunk-coverage", 120, |g| {
            let units = g.usize_in(0, 500);
            let chunk = g.usize_in(1, 40);
            let parts = chunk_ranges(units, chunk);
            let mut prev_end = 0;
            for p in &parts {
                prop_assert!(p.start == prev_end,
                    "gap or overlap before {p:?} (prev end {prev_end})");
                prop_assert!(p.end > p.start, "empty range {p:?}");
                prop_assert!(p.end - p.start <= chunk,
                    "oversized chunk {p:?} (chunk {chunk})");
                prev_end = p.end;
            }
            prop_assert!(prev_end == units,
                "tail units uncovered: {prev_end}/{units}");
            Ok(())
        });
    }

    #[test]
    fn schedule_parse_and_session_default() {
        assert_eq!(Schedule::parse("static"), Some(Schedule::Static));
        assert_eq!(Schedule::parse(" Stealing "),
                   Some(Schedule::Stealing));
        assert_eq!(Schedule::parse("steal"), Some(Schedule::Stealing));
        assert_eq!(Schedule::parse("AUTO"), Some(Schedule::Auto));
        assert_eq!(Schedule::parse("guided"), None);
        for s in [Schedule::Static, Schedule::Stealing, Schedule::Auto] {
            assert_eq!(Schedule::parse(s.name()), Some(s),
                "name() must round-trip through parse()");
        }
        // No parallel test depends on the ambient default (kernels take
        // the schedule verbatim), so briefly setting the override is
        // safe; it is cleared before returning.
        set_schedule(Some(Schedule::Stealing));
        assert_eq!(default_schedule(), Schedule::Stealing);
        set_schedule(None);
        let ambient = default_schedule();
        assert!(matches!(ambient, Schedule::Static | Schedule::Stealing
                                  | Schedule::Auto));
    }

    #[test]
    fn auto_steals_only_when_there_is_slack() {
        assert!(use_stealing(Schedule::Stealing, 1, 8));
        assert!(!use_stealing(Schedule::Static, 100, 2));
        assert!(use_stealing(Schedule::Auto, 9, 8));
        assert!(!use_stealing(Schedule::Auto, 8, 8),
            "one unit per worker leaves nothing to rebalance");
        assert!(!use_stealing(Schedule::Auto, 1, 4));
        // chunk sizing: ~4 chunks per worker, never zero units
        assert_eq!(steal_chunk(100, 4), 6);
        assert_eq!(steal_chunk(3, 4), 1);
        assert_eq!(steal_chunk(0, 4), 1);
    }

    #[test]
    fn macro_tile_row_ranges_tile_ragged_shapes_exactly() {
        // Unit ranges converted to row ranges (the way every par kernel
        // does it) must tile 0..m exactly, ragged last tile included.
        check("partition-rows", 80, |g| {
            let m = g.usize_in(0, 400);
            let unit = g.usize_in(1, 37);
            let workers = g.usize_in(1, 9);
            let parts = partition_units(m.div_ceil(unit), workers);
            let mut row = 0;
            for p in &parts {
                let lo = p.start * unit;
                let hi = (p.end * unit).min(m);
                prop_assert!(lo == row && hi > lo,
                    "row block [{lo},{hi}) does not continue from {row}");
                row = hi;
            }
            prop_assert!(row == m, "rows covered {row}/{m}");
            Ok(())
        });
    }

    const SCHEDULES: [Schedule; 3] =
        [Schedule::Static, Schedule::Stealing, Schedule::Auto];

    #[test]
    fn parallel_matmul_is_bit_identical_to_the_sequential_kernel() {
        // The acceptance property: stealing == static == sequential,
        // bit for bit, at every tested thread count over ragged shapes
        // (units < workers and single-macro-tile cases included by the
        // random geometry).
        check("par-matmul", 25, |g| {
            let (m, k, n) =
                (g.usize_in(1, 60), g.usize_in(1, 24), g.usize_in(1, 24));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let t = rand_tiles(g);
            let mut want = vec![0.0f32; m * n];
            matmul_tiled(&a, &b, &mut want, m, k, n, &t);
            for threads in [1usize, 2, 4, 7] {
                for sched in SCHEDULES {
                    let mut got = vec![7.0f32; m * n];
                    matmul_exec(&a, &b, &mut got, m, k, n, &t,
                                &pinned(threads, sched));
                    prop_assert!(got == want,
                        "parallel matmul diverged at {threads} threads \
                         under {sched:?}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_bias_and_transpose_variants_match_sequential() {
        check("par-matmul-variants", 20, |g| {
            let (m, k, n) =
                (g.usize_in(1, 40), g.usize_in(1, 20), g.usize_in(1, 20));
            let t = rand_tiles(g);
            // bias variant
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let bias = g.f32_vec(n, 2.0);
            let mut want = vec![0.0f32; m * n];
            matmul_bias_tiled(&a, &b, &bias, &mut want, m, k, n, &t);
            for sched in SCHEDULES {
                let mut got = vec![3.0f32; m * n];
                matmul_bias_exec(&a, &b, &bias, &mut got, m, k, n, &t,
                                 &pinned(3, sched));
                prop_assert!(got == want,
                    "parallel bias matmul diverged under {sched:?}");
            }
            // transpose-acc variant (a stored [k×m], accumulating)
            let a_t = g.f32_vec(k * m, 2.0);
            let init = g.f32_vec(m * n, 1.0);
            let mut want = init.clone();
            matmul_tn_acc_tiled(&a_t, &b, &mut want, k, m, n, &t);
            for sched in SCHEDULES {
                let mut got = init.clone();
                matmul_tn_acc_exec(&a_t, &b, &mut got, k, m, n, &t,
                                   &pinned(5, sched));
                prop_assert!(got == want,
                    "parallel tn matmul diverged under {sched:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn gate_shape_single_macro_tile_still_shards() {
        // 512^3 — the CI scaling gate — is exactly ONE Westmere MC
        // block; the refined shard unit must still split it across all
        // four workers instead of degenerating to the sequential path.
        let t = TileConfig::westmere_workers(4);
        let unit = shard_unit(t.mc, 512, 4);
        assert_eq!(partition_units(512usize.div_ceil(unit), 4).len(), 4,
            "512-row matmul must shard 4 ways (unit {unit})");
        // same story for a low-dimensional scan: pair_tiles clamps the
        // query tile at 512 rows, which must not serialise the workers
        assert_eq!(
            partition_units(1024usize.div_ceil(shard_unit(512, 1024, 4)),
                            4).len(),
            4, "1024 queries at qt=512 must shard 4 ways");
        // sub-macro-tile sharding stays bit-identical (m <= mc) — under
        // both schedules
        let mut g = Gen::new(99);
        let (m, k, n) = (64usize, 20, 20);
        let a = g.f32_vec(m * k, 2.0);
        let b = g.f32_vec(k * n, 2.0);
        let big = TileConfig { mc: 512, kc: 7, nc: 5, l1_f32: 4096 };
        let mut want = vec![0.0f32; m * n];
        matmul_tiled(&a, &b, &mut want, m, k, n, &big);
        for sched in SCHEDULES {
            let mut got = vec![0.0f32; m * n];
            matmul_exec(&a, &b, &mut got, m, k, n, &big,
                        &pinned(4, sched));
            assert_eq!(got, want, "diverged under {sched:?}");
        }
    }

    #[test]
    fn parallel_matmul_stays_within_matmul_tolerance_of_naive() {
        // The ISSUE parity contract, end to end: ≤ 1e-4 vs the naive
        // oracle (inherited from the sequential kernel's 4-deep groups).
        check("par-matmul-naive", 10, |g| {
            let (m, k, n) =
                (g.usize_in(1, 30), g.usize_in(1, 30), g.usize_in(1, 30));
            let a = g.f32_vec(m * k, 1.0);
            let b = g.f32_vec(k * n, 1.0);
            let mut want = vec![0.0f32; m * n];
            matmul_naive(&a, &b, &mut want, m, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_exec(&a, &b, &mut got, m, k, n,
                        &TileConfig::westmere_workers(4),
                        &pinned(4, Schedule::Stealing));
            for i in 0..want.len() {
                prop_assert!((want[i] - got[i]).abs() <= 1e-4,
                    "c[{i}]: {} vs {}", want[i], got[i]);
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_distances_are_bit_identical_to_sequential() {
        check("par-distance", 20, |g| {
            let d = g.usize_in(1, 16);
            let n = g.usize_in(0, 50);
            let nq = g.usize_in(0, 40);
            let train = g.f32_vec(n * d, 3.0);
            let queries = g.f32_vec(nq * d, 3.0);
            let t = TileConfig {
                mc: 1,
                kc: 1,
                nc: 1,
                l1_f32: g.usize_in(2, 32) * d,
            };
            let mut want = vec![0.0f32; nq * n];
            pairwise_sq_dists_tiled(&train, &queries, d, &mut want, &t);
            for threads in [1usize, 2, 4, 7] {
                for sched in SCHEDULES {
                    let mut got = vec![-1.0f32; nq * n];
                    pairwise_sq_dists_exec(
                        &train, &queries, d, &[], &[], &mut got, &t,
                        &pinned(threads, sched)
                            .with_algo(DistanceAlgo::Exact));
                    prop_assert!(got == want,
                        "parallel distances diverged at {threads} \
                         threads under {sched:?}");
                }
            }
            // and the naive oracle agrees bit-for-bit too
            let mut naive = vec![0.0f32; nq * n];
            pairwise_sq_dists_naive(&train, &queries, d, &mut naive);
            prop_assert!(naive == want, "tiled distances diverged");
            Ok(())
        });
    }

    #[test]
    fn gathered_distances_match_the_scalar_loop_bit_for_bit() {
        use crate::kernels::distance::sq_dist;
        check("par-gather-distance", 15, |g| {
            let d = g.usize_in(1, 12);
            let n = g.usize_in(1, 40);
            let features = g.f32_vec(n * d, 3.0);
            // the Exact path never reads the cache, but the gather
            // engine's seam always carries one
            let cache = NormCache::compute(&features, d);
            let train_idx: Vec<usize> =
                (0..g.usize_in(0, 30)).map(|_| g.usize_in(0, n - 1))
                                      .collect();
            let query_idx: Vec<usize> =
                (0..g.usize_in(0, 15)).map(|_| g.usize_in(0, n - 1))
                                      .collect();
            let t = TileConfig {
                mc: 1,
                kc: 1,
                nc: 1,
                l1_f32: g.usize_in(2, 16) * d,
            };
            for threads in [1usize, 3, 5] {
                let got = pairwise_sq_dists_gather_exec(
                    &features, d, &train_idx, &query_idx, &cache, &t,
                    &pinned(threads, Schedule::Stealing)
                        .with_algo(DistanceAlgo::Exact));
                for (q, &qi) in query_idx.iter().enumerate() {
                    for (j, &ji) in train_idx.iter().enumerate() {
                        let want = sq_dist(
                            &features[qi * d..(qi + 1) * d],
                            &features[ji * d..(ji + 1) * d]);
                        let have = got[q * train_idx.len() + j];
                        prop_assert!(
                            want.to_bits() == have.to_bits(),
                            "gathered distance diverged at ({q},{j}), \
                             {threads} threads");
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_gemm_distances_are_bit_identical_to_sequential() {
        // Query-row fan-out must not change a single bit of the Gemm
        // formulation: per-row accumulation depends only on the tile
        // config's kc blocking, never on the worker that computes it.
        check("par-gemm-distance", 15, |g| {
            let d = g.usize_in(1, 12);
            let n = g.usize_in(0, 40);
            let nq = g.usize_in(0, 30);
            let train = g.f32_vec(n * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let t = TileConfig {
                mc: g.usize_in(1, 7),
                kc: g.usize_in(1, 7),
                nc: g.usize_in(1, 7),
                l1_f32: g.usize_in(2, 16) * d,
            };
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let mut want = vec![0.0f32; nq * n];
            pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn,
                                   &mut want, &t);
            for threads in [1usize, 2, 4, 7] {
                for sched in SCHEDULES {
                    let mut got = vec![-1.0f32; nq * n];
                    pairwise_sq_dists_gemm_exec(
                        &train, &queries, d, &tn, &qn, &mut got, &t,
                        &pinned(threads, sched));
                    prop_assert!(got == want,
                        "parallel gemm distances diverged at {threads} \
                         threads under {sched:?}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_distances_stay_within_exact_tolerance_at_every_thread_count() {
        // The ISSUE acceptance property: Gemm ≤ 1e-4 relative vs the
        // Exact oracle AND clamped ≥ 0, across ragged shapes at
        // 1/2/4/7 threads under both explicit schedules.
        check("par-gemm-vs-exact", 12, |g| {
            let d = g.usize_in(1, 12);
            let n = g.usize_in(1, 40);
            let nq = g.usize_in(1, 24);
            let train = g.f32_vec(n * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let t = TileConfig {
                mc: g.usize_in(1, 7),
                kc: g.usize_in(1, 7),
                nc: g.usize_in(1, 7),
                l1_f32: g.usize_in(2, 16) * d,
            };
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let mut exact = vec![0.0f32; nq * n];
            pairwise_sq_dists_naive(&train, &queries, d, &mut exact);
            for threads in [1usize, 2, 4, 7] {
                for sched in [Schedule::Static, Schedule::Stealing] {
                    let mut gemm = vec![-1.0f32; nq * n];
                    pairwise_sq_dists_gemm_exec(
                        &train, &queries, d, &tn, &qn, &mut gemm, &t,
                        &pinned(threads, sched));
                    for i in 0..exact.len() {
                        prop_assert!(gemm[i] >= 0.0,
                            "gemm[{i}] = {} escaped the clamp at \
                             {threads} threads under {sched:?}", gemm[i]);
                        let tol = 1e-4 * exact[i].abs().max(1.0);
                        prop_assert!((gemm[i] - exact[i]).abs() <= tol,
                            "gemm[{i}] {} vs exact {} at {threads} \
                             threads under {sched:?}", gemm[i], exact[i]);
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gather_algo_gemm_reuses_the_norm_cache_bit_for_bit() {
        // The gather engine under Gemm must equal the dense Gemm kernel
        // run on the gathered buffers with norms gathered from the
        // dataset-level cache — and under Exact it must stay the
        // per-pair scalar formulation exactly.
        check("gather-algo-gemm", 12, |g| {
            let d = g.usize_in(1, 10);
            let n = g.usize_in(1, 30);
            let features = g.f32_vec(n * d, 1.0);
            let cache = NormCache::compute(&features, d);
            let train_idx: Vec<usize> =
                (0..g.usize_in(0, 25)).map(|_| g.usize_in(0, n - 1))
                                      .collect();
            let query_idx: Vec<usize> =
                (0..g.usize_in(0, 12)).map(|_| g.usize_in(0, n - 1))
                                      .collect();
            let t = TileConfig {
                mc: g.usize_in(1, 7),
                kc: g.usize_in(1, 7),
                nc: g.usize_in(1, 7),
                l1_f32: g.usize_in(2, 16) * d,
            };
            let train = gather_rows(&features, d, &train_idx);
            let queries = gather_rows(&features, d, &query_idx);
            let mut want =
                vec![0.0f32; query_idx.len() * train_idx.len()];
            pairwise_sq_dists_gemm(&train, &queries, d,
                                   &cache.gather(&train_idx),
                                   &cache.gather(&query_idx), &mut want,
                                   &t);
            let mut exact_want =
                vec![0.0f32; query_idx.len() * train_idx.len()];
            pairwise_sq_dists_naive(&train, &queries, d,
                                    &mut exact_want);
            for threads in [1usize, 3, 5] {
                let got = pairwise_sq_dists_gather_exec(
                    &features, d, &train_idx, &query_idx, &cache, &t,
                    &pinned(threads, Schedule::Stealing)
                        .with_algo(DistanceAlgo::Gemm));
                prop_assert!(got == want,
                    "gather gemm diverged at {threads} threads");
                let exact = pairwise_sq_dists_gather_exec(
                    &features, d, &train_idx, &query_idx, &cache, &t,
                    &pinned(threads, Schedule::Static)
                        .with_algo(DistanceAlgo::Exact));
                prop_assert!(exact == exact_want,
                    "gather exact diverged from the per-pair oracle");
            }
            Ok(())
        });
    }

    #[test]
    fn exec_resolves_auto_algo_once_for_the_whole_call() {
        // Auto below the MAC threshold must run the Exact fan-out;
        // explicit Gemm must run the gemm fan-out — and the dispatch
        // happens once in `resolve()`, before the fan-out, so a split
        // pass cannot mix formulations.
        let mut g = Gen::new(23);
        let (d, n, nq) = (5usize, 30, 12);
        let train = g.f32_vec(n * d, 1.0);
        let queries = g.f32_vec(nq * d, 1.0);
        let t = TileConfig::westmere_workers(4);
        let tn = row_sq_norms(&train, d);
        let qn = row_sq_norms(&queries, d);
        let mut exact = vec![0.0f32; nq * n];
        pairwise_sq_dists_exec(&train, &queries, d, &[], &[], &mut exact,
                               &t, &pinned(4, Schedule::Static)
                                   .with_algo(DistanceAlgo::Exact));
        let mut gemm = vec![0.0f32; nq * n];
        pairwise_sq_dists_gemm_exec(&train, &queries, d, &tn, &qn,
                                    &mut gemm, &t,
                                    &pinned(4, Schedule::Static));
        assert!(nq * n * d < crate::kernels::distance::MIN_GEMM_WORK);
        let mut got = vec![0.0f32; nq * n];
        pairwise_sq_dists_exec(&train, &queries, d, &[], &[], &mut got,
                               &t, &pinned(4, Schedule::Static));
        assert_eq!(got, exact, "small-work Auto must stay Exact");
        let mut got = vec![0.0f32; nq * n];
        pairwise_sq_dists_exec(&train, &queries, d, &tn, &qn, &mut got,
                               &t, &pinned(4, Schedule::Static)
                                   .with_algo(DistanceAlgo::Gemm));
        assert_eq!(got, gemm, "explicit Gemm must run the gemm fan-out");
    }

    /// The schedule-independent reference: per-macro-tile partials
    /// accumulated inline, reduced in tile-index order.
    fn coupled_tile_reference(
        w0: &[f32],
        w1: &[f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
        lam: f32,
        t: &TileConfig,
    ) -> ((Vec<f32>, f32), (Vec<f32>, f32)) {
        let d = w0.len();
        let b = y.len();
        let unit = t.coupled_rows().max(1);
        let units = b.div_ceil(unit);
        if units <= 1 {
            return coupled_step_tiled(w0, w1, x, y, lr, lam, t);
        }
        let partials: Vec<CoupledPartial> = (0..units)
            .map(|u| {
                let lo = u * unit;
                let hi = ((u + 1) * unit).min(b);
                coupled_accumulate(w0, w1, &x[lo * d..hi * d],
                                   &y[lo..hi], t)
            })
            .collect();
        coupled_finalize(w0, w1, reduce_partials(partials, d), b, lr, lam)
    }

    #[test]
    fn parallel_coupled_is_invariant_across_threads_and_schedules() {
        // The work-stealing acceptance property for the reduction
        // kernel: partials are merged by tile index, never by
        // completion order, so every (threads, schedule) combination —
        // the sequential threads=1 engine included — produces the same
        // bits as the tile-order reference.
        check("par-coupled", 12, |g| {
            let d = g.usize_in(1, 40);
            let b = g.usize_in(1, 200);
            let w0 = g.f32_vec(d, 1.0);
            let w1 = g.f32_vec(d, 1.0);
            let x = g.f32_vec(b * d, 2.0);
            let y: Vec<f32> = (0..b)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            // tiny coupled tiles force real multi-block partitions
            let t = TileConfig {
                mc: 3,
                kc: g.usize_in(1, 9),
                nc: 3,
                l1_f32: g.usize_in(8, 96),
            };
            let want = coupled_tile_reference(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t);
            for threads in [1usize, 2, 4, 7] {
                for sched in SCHEDULES {
                    let got = coupled_step_exec(
                        &w0, &w1, &x, &y, linear::LR, linear::LAMBDA,
                        &t, &pinned(threads, sched));
                    prop_assert!(got == want,
                        "coupled step diverged at {threads} threads \
                         under {sched:?}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_macro_tile_coupled_batch_is_the_sequential_kernel() {
        // A batch that fits one coupled_rows() macro-tile must
        // short-circuit to coupled_step_tiled bit-for-bit at every
        // thread count (the degenerate units <= 1 case).
        let mut g = Gen::new(41);
        let d = 24;
        let t = TileConfig::westmere();
        let b = t.coupled_rows().min(40); // one macro-tile by definition
        let w0 = g.f32_vec(d, 1.0);
        let w1 = g.f32_vec(d, 1.0);
        let x = g.f32_vec(b * d, 2.0);
        let y: Vec<f32> =
            (0..b).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
        let seq = coupled_step_tiled(&w0, &w1, &x, &y, linear::LR,
                                     linear::LAMBDA, &t);
        for threads in [1usize, 4, 7] {
            for sched in SCHEDULES {
                let got = coupled_step_exec(&w0, &w1, &x, &y, linear::LR,
                                            linear::LAMBDA, &t,
                                            &pinned(threads, sched));
                assert_eq!(got, seq,
                    "single-tile batch diverged at {threads} threads \
                     under {sched:?}");
            }
        }
    }

    #[test]
    fn parallel_coupled_stays_within_tolerance_of_the_naive_oracle() {
        // ISSUE contract at N threads: the per-tile reduction may
        // reassociate the gradient sums, but never past 1e-4 — under
        // either schedule.
        check("par-coupled-tolerance", 6, |g| {
            let d = g.usize_in(80, 160);
            let b = g.usize_in(150, 300);
            let w0 = g.f32_vec(d, 0.5);
            let w1 = g.f32_vec(d, 0.5);
            let x = g.f32_vec(b * d, 1.0);
            let y: Vec<f32> = (0..b)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            let t = TileConfig::westmere_workers(4);
            let ((wl, ll), (ws, ls)) = linear::coupled_step_naive(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA);
            for sched in [Schedule::Static, Schedule::Stealing] {
                let ((wl2, ll2), (ws2, ls2)) = coupled_step_exec(
                    &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t,
                    &pinned(4, sched));
                for f in 0..d {
                    prop_assert!((wl[f] - wl2[f]).abs() < 1e-4,
                        "lr w[{f}] under {sched:?}");
                    prop_assert!((ws[f] - ws2[f]).abs() < 1e-4,
                        "svm w[{f}] under {sched:?}");
                }
                prop_assert!((ll - ll2).abs() < 1e-4, "lr loss");
                prop_assert!((ls - ls2).abs() < 1e-4, "svm loss");
            }
            Ok(())
        });
    }

    #[test]
    fn zero_and_degenerate_shapes_are_harmless() {
        let t = TileConfig::westmere();
        for sched in SCHEDULES {
            let mut c: Vec<f32> = Vec::new();
            matmul_exec(&[], &[], &mut c, 0, 0, 0, &t,
                        &pinned(4, sched));
            let mut c = vec![5.0f32; 3];
            matmul_exec(&[], &[], &mut c, 1, 0, 3, &t,
                        &pinned(4, sched));
            assert_eq!(c, vec![0.0; 3], "k = 0 must still zero C");
            let mut out: Vec<f32> = Vec::new();
            pairwise_sq_dists_exec(&[], &[], 2, &[], &[], &mut out, &t,
                                   &pinned(4, sched)
                                       .with_algo(DistanceAlgo::Exact));
            assert!(out.is_empty());
        }
    }

    #[test]
    fn effective_threads_keeps_small_work_sequential() {
        assert_eq!(effective_threads(8, MIN_PAR_WORK - 1), 1);
        assert_eq!(effective_threads(8, MIN_PAR_WORK), 8);
        assert_eq!(effective_threads(1, MIN_PAR_WORK), 1);
    }

    #[test]
    fn default_threads_honours_the_cli_override() {
        // No parallel test depends on the ambient default, so briefly
        // setting the override is safe even with concurrent tests (the
        // override is restored before returning).
        set_threads(3);
        assert_eq!(default_threads(), 3);
        set_threads(0);
        assert!(default_threads() >= 1);
    }

    /// Every `*_exec` entry under a fully pinned policy must reproduce
    /// the sequential kernel bit for bit: one randomized grid point per
    /// case sweeps the cross-kernel lattice in a single suite, on top
    /// of the per-kernel thread/schedule sweeps above.
    #[test]
    fn exec_api_matches_sequential_kernels_bit_for_bit() {
        check("exec-vs-sequential", 56, |g| {
            let m = g.usize_in(1, 40);
            let k = g.usize_in(1, 24);
            let n = g.usize_in(1, 40);
            let a = g.f32_vec(m * k, 1.0);
            let b = g.f32_vec(k * n, 1.0);
            let bias = g.f32_vec(n, 1.0);
            let t = rand_tiles(g);
            let threads = [1usize, 2, 4, 7][g.usize_in(0, 3)];
            let sched = SCHEDULES[g.usize_in(0, 2)];
            let pol = pinned(threads, sched);

            let mut want = vec![0.0f32; m * n];
            let mut got = vec![0.0f32; m * n];
            matmul_tiled(&a, &b, &mut want, m, k, n, &t);
            matmul_exec(&a, &b, &mut got, m, k, n, &t, &pol);
            prop_assert!(got == want, "matmul_exec != matmul_tiled");

            let mut want = vec![0.25f32; m * n];
            let mut got = vec![0.25f32; m * n];
            matmul_bias_tiled(&a, &b, &bias, &mut want, m, k, n, &t);
            matmul_bias_exec(&a, &b, &bias, &mut got, m, k, n, &t, &pol);
            prop_assert!(got == want, "bias exec != sequential");

            let at = g.f32_vec(k * m, 1.0);
            let mut want = vec![0.5f32; m * n];
            let mut got = vec![0.5f32; m * n];
            matmul_tn_acc_tiled(&at, &b, &mut want, k, m, n, &t);
            matmul_tn_acc_exec(&at, &b, &mut got, k, m, n, &t, &pol);
            prop_assert!(got == want, "tn exec != sequential");

            let d = g.usize_in(1, 12);
            let nt = g.usize_in(1, 30);
            let nq = g.usize_in(1, 30);
            let train = g.f32_vec(nt * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let mut want = vec![0.0f32; nq * nt];
            let mut got = vec![0.0f32; nq * nt];
            pairwise_sq_dists_tiled(&train, &queries, d, &mut want, &t);
            pairwise_sq_dists_exec(&train, &queries, d, &[], &[],
                                   &mut got, &t,
                                   &pol.with_algo(DistanceAlgo::Exact));
            prop_assert!(got == want, "exact dists exec != sequential");
            let mut want = vec![0.0f32; nq * nt];
            let mut got = vec![0.0f32; nq * nt];
            pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn,
                                   &mut want, &t);
            pairwise_sq_dists_exec(&train, &queries, d, &tn, &qn,
                                   &mut got, &t,
                                   &pol.with_algo(DistanceAlgo::Gemm));
            prop_assert!(got == want, "gemm dists exec != sequential");
            Ok(())
        });
    }

    /// The gather engine under a policy must equal the dense kernels
    /// run on explicitly gathered buffers, with norms gathered from the
    /// dataset-level `NormCache` on the Gemm path.
    #[test]
    fn gather_exec_matches_the_dense_kernels_bit_for_bit() {
        check("gather-exec-vs-dense", 24, |g| {
            let d = g.usize_in(1, 10);
            let rows = g.usize_in(4, 40);
            let features = g.f32_vec(rows * d, 1.0);
            let cache = NormCache::compute(&features, d);
            let ti: Vec<usize> =
                (0..g.usize_in(1, rows)).map(|_| g.usize_in(0, rows - 1))
                                        .collect();
            let qi: Vec<usize> =
                (0..g.usize_in(1, rows)).map(|_| g.usize_in(0, rows - 1))
                                        .collect();
            let t = rand_tiles(g);
            let train = gather_rows(&features, d, &ti);
            let queries = gather_rows(&features, d, &qi);
            for algo in [DistanceAlgo::Exact, DistanceAlgo::Gemm] {
                let mut want = vec![0.0f32; qi.len() * ti.len()];
                match algo {
                    DistanceAlgo::Gemm => pairwise_sq_dists_gemm(
                        &train, &queries, d, &cache.gather(&ti),
                        &cache.gather(&qi), &mut want, &t),
                    _ => pairwise_sq_dists_naive(&train, &queries, d,
                                                 &mut want),
                }
                for threads in [1usize, 4] {
                    let sched = SCHEDULES[g.usize_in(0, 2)];
                    let got = pairwise_sq_dists_gather_exec(
                        &features, d, &ti, &qi, &cache, &t,
                        &pinned(threads, sched).with_algo(algo));
                    prop_assert!(got == want,
                        "gather exec != dense ({algo:?}, {threads})");
                }
            }
            Ok(())
        });
    }

    /// Coupled step: `ExecPolicy::sequential()` IS the sequential
    /// kernel, and any pinned policy matches the tile-order reference
    /// bitwise.
    #[test]
    fn coupled_exec_matches_reference_and_sequential_policy() {
        check("coupled-exec", 24, |g| {
            let d = g.usize_in(1, 12);
            let b = g.usize_in(1, 60);
            let w0 = g.f32_vec(d, 0.5);
            let w1 = g.f32_vec(d, 0.5);
            let x = g.f32_vec(b * d, 1.0);
            let y: Vec<f32> = (0..b)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            let t = rand_tiles(g);
            let seq = coupled_step_tiled(&w0, &w1, &x, &y, linear::LR,
                                         linear::LAMBDA, &t);
            let via_policy = coupled_step_exec(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t,
                &ExecPolicy::sequential());
            prop_assert!(seq == via_policy,
                "sequential policy must be the sequential kernel");
            let want = coupled_tile_reference(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t);
            for threads in [2usize, 7] {
                let sched = SCHEDULES[g.usize_in(0, 2)];
                let e = coupled_step_exec(
                    &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t,
                    &pinned(threads, sched));
                prop_assert!(e == want,
                    "coupled exec != tile-order reference");
            }
            Ok(())
        });
    }

    /// The shared-pack parallel forward: a `PackedPanel` packed once
    /// and fanned out read-only must equal the sequential prepacked
    /// kernel bit for bit at every thread count and schedule — and,
    /// because packed bits are tier-invariant, forcing the scalar
    /// micro-kernel mid-flight must not change a single bit either.
    #[test]
    fn prepacked_fan_out_is_bit_stable_and_tier_invariant() {
        check("prepacked-fan-out", 32, |g| {
            let m = g.usize_in(1, 48);
            let k = g.usize_in(1, 24);
            let n = g.usize_in(1, 40);
            let a = g.f32_vec(m * k, 1.0);
            let b = g.f32_vec(k * n, 1.0);
            let bias = g.f32_vec(n, 1.0);
            let t = rand_tiles(g);
            let pb = PackedPanel::pack(&b, k, n, t.kc);
            let mut want = vec![0.0f32; m * n];
            matmul_bias_prepacked(&a, &pb, &bias, &mut want, m, &t);
            for threads in [1usize, 2, 4, 7] {
                for sched in SCHEDULES {
                    let pol = ExecPolicy::auto()
                        .with_threads(threads)
                        .with_schedule(sched);
                    let mut got = vec![0.0f32; m * n];
                    matmul_bias_prepacked_exec(&a, &pb, &bias, &mut got,
                                               m, &t, &pol);
                    prop_assert!(got == want,
                        "prepacked fan-out bits ({threads}, {sched:?})");
                    // Tier invariance: forcing scalar is safe to flip
                    // globally because every tier is bit-identical —
                    // any concurrently running test just takes the
                    // scalar path and still sees the same bits.
                    set_force_scalar(Some(true));
                    let mut forced = vec![0.0f32; m * n];
                    matmul_bias_prepacked_exec(&a, &pb, &bias,
                                               &mut forced, m, &t, &pol);
                    set_force_scalar(None);
                    prop_assert!(forced == want,
                        "forced-scalar bits ({threads}, {sched:?})");
                }
            }
            Ok(())
        });
    }
}
