//! Cache-blocked matrix multiplication (the paper's Fig 3 pattern).
//!
//! All matrices are row-major f32. Two loop orders are provided:
//!
//! * **naive** — `i-j-k` dot products, one output element at a time. The
//!   inner loop strides `B` by `n` elements, so for any `B` larger than a
//!   cache level every step of the reduction misses: this is the
//!   row-at-a-time baseline the paper argues against.
//! * **tiled** — `i-k-j` inside `NC × KC × MC` blocks: the inner loop
//!   walks one row of `B` and one row of `C` with unit stride while a
//!   `kc × nc` panel of `B` stays L1-resident and an `mc × kc` block of
//!   `A` stays L2-resident (sizes from [`TileConfig`]).
//!
//! Both orders sum exactly the same multiset of products per `C[i,j]`,
//! over `p` in ascending order; the tiled micro-kernel groups four `p`
//! terms before touching `C` (see [`matmul_acc_tiled`]), so results may
//! differ from the naive reference only by that local reassociation —
//! property tests assert ≤ 1e-4 across random ragged shapes. The
//! transpose variant keeps strictly naive accumulation order and is
//! bit-identical to its reference.
//!
//! A zero-skip on the `A` scalars is kept from the original MLP loop
//! nest: ReLU activations make `A` sparse in the backprop paths and
//! skipping a row of multiplies per dead group is free for dense inputs.
//!
//! A third path — **packed** ([`matmul_packed`] and the `prepacked`
//! variants) — adds the BLIS-style register rung on top of the cache
//! blocking: operands are packed once per macro-tile into aligned
//! [`PackedPanel`]/A-panel buffers ([`super::pack`]) and multiplied by
//! an `MR × NR` SIMD micro-kernel. Unlike the tiled kernel it performs
//! NO zero-skip and NO group reassociation: each C element is one
//! `p`-ascending mul/add chain, so the packed path is **bit-identical
//! to [`matmul_naive`]** at every [`super::pack::MicroKernel`] tier and
//! for every tile configuration.

use super::pack::{
    pack_a_block, round_up, run_micro, MicroKernel, PackedBuf,
    PackedPanel, MR, NR,
};
use super::tile::TileConfig;

/// Naive reference: `C = A·B` via `i-j-k` dot products.
/// `a` is `[m×k]`, `b` is `[k×n]`, `c` is `[m×n]` (overwritten).
pub fn matmul_naive(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// Cache-blocked `C = A·B` (overwrites `c`): `i-k-j` order inside
/// `MC/KC/NC` tiles, ragged edges handled by clamping each tile.
pub fn matmul_tiled(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    matmul_acc_tiled(a, b, c, m, k, n, t);
}

/// Cache-blocked `C += A·B` — the accumulating core of [`matmul_tiled`].
///
/// The micro-kernel processes four `p` values per sweep of the `C` row:
/// that halves the dominant `C`-row load/store traffic twice over and is
/// what pushes the tiled path past 2× over the naive order even when
/// `B` still fits in an outer cache level. Within each 4-term group the
/// partial products are summed before touching `C`, so results can
/// differ from the naive reference in the last bits (≤ 1e-4 —
/// property-tested); the multiset of products is identical.
pub fn matmul_acc_tiled(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let (mc, kc, nc) = (t.mc.max(1), t.kc.max(1), t.nc.max(1));
    for jc in (0..n).step_by(nc) {
        let jhi = (jc + nc).min(n);
        for pc in (0..k).step_by(kc) {
            let phi = (pc + kc).min(k);
            for ic in (0..m).step_by(mc) {
                let ihi = (ic + mc).min(m);
                for i in ic..ihi {
                    let arow = &a[i * k..(i + 1) * k];
                    let crow = &mut c[i * n + jc..i * n + jhi];
                    let mut p = pc;
                    while p + 4 <= phi {
                        let a0 = arow[p];
                        let a1 = arow[p + 1];
                        let a2 = arow[p + 2];
                        let a3 = arow[p + 3];
                        // ReLU sparsity: skip fully dead groups
                        if a0 != 0.0 || a1 != 0.0 || a2 != 0.0
                            || a3 != 0.0 {
                            let b0 = &b[p * n + jc..p * n + jhi];
                            let b1 =
                                &b[(p + 1) * n + jc..(p + 1) * n + jhi];
                            let b2 =
                                &b[(p + 2) * n + jc..(p + 2) * n + jhi];
                            let b3 =
                                &b[(p + 3) * n + jc..(p + 3) * n + jhi];
                            for ((((cv, &v0), &v1), &v2), &v3) in crow
                                .iter_mut()
                                .zip(b0)
                                .zip(b1)
                                .zip(b2)
                                .zip(b3)
                            {
                                *cv += a0 * v0 + a1 * v1 + a2 * v2
                                    + a3 * v3;
                            }
                        }
                        p += 4;
                    }
                    while p < phi {
                        let av = arow[p];
                        if av != 0.0 {
                            let brow = &b[p * n + jc..p * n + jhi];
                            for (cv, &bv) in crow.iter_mut().zip(brow) {
                                *cv += av * bv;
                            }
                        }
                        p += 1;
                    }
                }
            }
        }
    }
}

/// Cache-blocked `C = bias ⊕ A·B` (bias broadcast to every row) — the NN
/// forward primitive `z = a_prev·W + b`.
pub fn matmul_bias_tiled(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(bias.len(), n);
    assert_eq!(c.len(), m * n);
    for row in c.chunks_exact_mut(n.max(1)) {
        row.copy_from_slice(bias);
    }
    matmul_acc_tiled(a, b, c, m, k, n, t);
}

/// Naive reference for `C += Aᵀ·B` with `a` stored `[k×m]` row-major
/// (so the product is `[m×n]`) — the backprop `dW = a_prevᵀ·δ` shape.
pub fn matmul_tn_acc_naive(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for p in 0..k {
        for i in 0..m {
            let av = a[p * m + i];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
}

/// Cache-blocked `C += Aᵀ·B` (`a` stored `[k×m]` row-major): the rows of
/// `B` and `C` are walked with unit stride while a `kc`-deep slab of both
/// operands stays cache-resident. Accumulation order per element matches
/// the naive reference exactly.
pub fn matmul_tn_acc_tiled(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    t: &TileConfig,
) {
    matmul_tn_acc_rows(a, b, c, k, m, n, t, 0, m);
}

/// Row-range core of [`matmul_tn_acc_tiled`]: accumulates rows
/// `i0..i1` of `C` (passed as the `(i1-i0) × n` slice `c_rows`) while
/// reading the full `[k×m]` transposed operand. Per-element accumulation
/// stays `p`-ascending for any row split, so the parallel wrapper that
/// hands disjoint row ranges to workers is bit-identical to the
/// sequential kernel.
pub(crate) fn matmul_tn_acc_rows(
    a: &[f32],
    b: &[f32],
    c_rows: &mut [f32],
    k: usize,
    m: usize,
    n: usize,
    t: &TileConfig,
    i0: usize,
    i1: usize,
) {
    assert!(i0 <= i1 && i1 <= m);
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c_rows.len(), (i1 - i0) * n);
    let (mc, kc, nc) = (t.mc.max(1), t.kc.max(1), t.nc.max(1));
    for jc in (0..n).step_by(nc) {
        let jhi = (jc + nc).min(n);
        for pc in (0..k).step_by(kc) {
            let phi = (pc + kc).min(k);
            for ic in (i0..i1).step_by(mc) {
                let ihi = (ic + mc).min(i1);
                for p in pc..phi {
                    let arow = &a[p * m..(p + 1) * m];
                    let brow = &b[p * n + jc..p * n + jhi];
                    for i in ic..ihi {
                        let av = arow[i];
                        if av == 0.0 {
                            continue;
                        }
                        let crow = &mut c_rows
                            [(i - i0) * n + jc..(i - i0) * n + jhi];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Packed-operand `C = A·B` (overwrites `c`): packs `b` once with the
/// config's `kc` blocking, then runs [`matmul_acc_prepacked`].
/// Bit-identical to [`matmul_naive`] (see module docs).
pub fn matmul_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    let pb = PackedPanel::pack(b, k, n, t.kc.max(1));
    matmul_acc_prepacked(a, &pb, c, m, t);
}

/// Packed-operand `C += A·B` — packs `b` per call; prefer
/// [`matmul_acc_prepacked`] when `b` is reused across calls.
pub fn matmul_acc_packed(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(b.len(), k * n);
    let pb = PackedPanel::pack(b, k, n, t.kc.max(1));
    matmul_acc_prepacked(a, &pb, c, m, t);
}

/// Packed-operand `C = bias ⊕ A·B` — the NN forward primitive on the
/// packed path.
pub fn matmul_bias_packed(
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    t: &TileConfig,
) {
    assert_eq!(b.len(), k * n);
    let pb = PackedPanel::pack(b, k, n, t.kc.max(1));
    matmul_bias_prepacked(a, &pb, bias, c, m, t);
}

/// `C += A·B` against an already-packed `B` operand, on the session's
/// dispatched micro-kernel tier. This is the reuse entry point: the
/// GEMM distance engine packs each train panel once per sweep,
/// `NativeMlp` packs its forward weights once at fit time, and every
/// subsequent multiply streams the packed bytes straight into the
/// register block.
pub fn matmul_acc_prepacked(
    a: &[f32],
    pb: &PackedPanel,
    c: &mut [f32],
    m: usize,
    t: &TileConfig,
) {
    matmul_acc_prepacked_with(super::pack::micro_kernel(), a, pb, c, m,
                              t);
}

/// `C = bias ⊕ A·B` against an already-packed `B` operand.
pub fn matmul_bias_prepacked(
    a: &[f32],
    pb: &PackedPanel,
    bias: &[f32],
    c: &mut [f32],
    m: usize,
    t: &TileConfig,
) {
    assert_eq!(bias.len(), pb.n());
    assert_eq!(c.len(), m * pb.n());
    for row in c.chunks_exact_mut(pb.n().max(1)) {
        row.copy_from_slice(bias);
    }
    matmul_acc_prepacked(a, pb, c, m, t);
}

/// Explicit-tier core of [`matmul_acc_prepacked`] — the entry point
/// the tier-parity property tests drive directly. Panics if `kernel`
/// is not available on this CPU.
///
/// Loop structure (BLIS loops 4–1 with `NC` subsumed by the prepacked
/// operand): per depth block of `pb`, per `mc`-row block of `A` (packed
/// here, once per element), per `NR`-column panel of packed B, per
/// `MR`-row panel of packed A, one micro-kernel call. Accumulators are
/// seeded from `C`, so per-element bits are independent of every
/// blocking parameter.
pub fn matmul_acc_prepacked_with(
    kernel: MicroKernel,
    a: &[f32],
    pb: &PackedPanel,
    c: &mut [f32],
    m: usize,
    t: &TileConfig,
) {
    let (k, n) = (pb.k(), pb.n());
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let mc = round_up(t.mc.max(1), MR);
    let mut apack =
        PackedBuf::zeroed(mc.min(round_up(m, MR)) * pb.kc().max(1));
    for (bi, (p0, kb)) in pb.depth_blocks().enumerate() {
        for ic in (0..m).step_by(mc) {
            let rows = (ic + mc).min(m) - ic;
            let apanels = rows.div_ceil(MR);
            pack_a_block(a, k, ic, rows, p0, kb, apack.as_mut_slice());
            let apack = apack.as_slice();
            for jp in 0..pb.col_panels() {
                let bp = pb.panel(bi, jp);
                let j0 = jp * NR;
                let cols = NR.min(n - j0);
                for ip in 0..apanels {
                    let i0 = ic + ip * MR;
                    let live = MR.min(m - i0);
                    let ap =
                        &apack[ip * MR * kb..ip * MR * kb + MR * kb];
                    let mut acc = [0.0f32; MR * NR];
                    for r in 0..live {
                        let s = (i0 + r) * n + j0;
                        acc[r * NR..r * NR + cols]
                            .copy_from_slice(&c[s..s + cols]);
                    }
                    run_micro(kernel, ap, bp, kb, &mut acc);
                    for r in 0..live {
                        let s = (i0 + r) * n + j0;
                        c[s..s + cols]
                            .copy_from_slice(&acc[r * NR..r * NR + cols]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn rand_tiles(g: &mut Gen) -> TileConfig {
        // Deliberately tiny, non-power-of-two tiles so every
        // divisibility case (including tiles larger than the matrix)
        // is exercised.
        TileConfig {
            mc: g.usize_in(1, 17),
            kc: g.usize_in(1, 17),
            nc: g.usize_in(1, 17),
            l1_f32: 1 << g.usize_in(6, 12),
        }
    }

    fn assert_close(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if (x - y).abs() > 1e-4 {
                return Err(format!("{what}[{i}]: {x} vs {y}"));
            }
        }
        Ok(())
    }

    #[test]
    fn tiled_matches_naive_across_ragged_shapes() {
        check("matmul-tiled-vs-naive", 40, |g| {
            let (m, k, n) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let t = rand_tiles(g);
            let mut c_naive = vec![0.0f32; m * n];
            let mut c_tiled = vec![7.0f32; m * n]; // must be overwritten
            matmul_naive(&a, &b, &mut c_naive, m, k, n);
            matmul_tiled(&a, &b, &mut c_tiled, m, k, n, &t);
            assert_close(&c_naive, &c_tiled, "c")?;
            Ok(())
        });
    }

    #[test]
    fn tiled_matches_naive_with_autotuned_config() {
        check("matmul-autotuned", 10, |g| {
            let (m, k, n) =
                (g.usize_in(1, 30), g.usize_in(1, 30), g.usize_in(1, 30));
            let a = g.f32_vec(m * k, 1.0);
            let b = g.f32_vec(k * n, 1.0);
            let mut c_naive = vec![0.0f32; m * n];
            let mut c_tiled = vec![0.0f32; m * n];
            matmul_naive(&a, &b, &mut c_naive, m, k, n);
            matmul_tiled(&a, &b, &mut c_tiled, m, k, n,
                         &TileConfig::westmere());
            assert_close(&c_naive, &c_tiled, "c")?;
            Ok(())
        });
    }

    #[test]
    fn bias_variant_adds_bias_once_per_row() {
        check("matmul-bias", 25, |g| {
            let (m, k, n) =
                (g.usize_in(1, 20), g.usize_in(1, 20), g.usize_in(1, 20));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let bias = g.f32_vec(n, 2.0);
            let t = rand_tiles(g);
            let mut want = vec![0.0f32; m * n];
            matmul_naive(&a, &b, &mut want, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    want[i * n + j] += bias[j];
                }
            }
            let mut got = vec![0.0f32; m * n];
            matmul_bias_tiled(&a, &b, &bias, &mut got, m, k, n, &t);
            assert_close(&want, &got, "z")?;
            Ok(())
        });
    }

    #[test]
    fn transpose_acc_matches_naive_and_accumulates() {
        check("matmul-tn", 40, |g| {
            let (k, m, n) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let a = g.f32_vec(k * m, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let init = g.f32_vec(m * n, 1.0);
            let t = rand_tiles(g);
            let mut c_naive = init.clone();
            let mut c_tiled = init;
            matmul_tn_acc_naive(&a, &b, &mut c_naive, k, m, n);
            matmul_tn_acc_tiled(&a, &b, &mut c_tiled, k, m, n, &t);
            assert_close(&c_naive, &c_tiled, "dw")?;
            Ok(())
        });
    }

    #[test]
    fn transpose_acc_agrees_with_plain_matmul_on_transposed_input() {
        check("matmul-tn-vs-plain", 20, |g| {
            let (k, m, n) =
                (g.usize_in(1, 24), g.usize_in(1, 24), g.usize_in(1, 24));
            let a_t = g.f32_vec(k * m, 2.0); // [k×m]
            let b = g.f32_vec(k * n, 2.0);
            // materialise Aᵀᵀ = A as [m×k] and multiply the plain way
            let mut a = vec![0.0f32; m * k];
            for p in 0..k {
                for i in 0..m {
                    a[i * k + p] = a_t[p * m + i];
                }
            }
            let mut want = vec![0.0f32; m * n];
            matmul_naive(&a, &b, &mut want, m, k, n);
            let mut got = vec![0.0f32; m * n];
            matmul_tn_acc_tiled(&a_t, &b, &mut got, k, m, n,
                                &rand_tiles(g));
            assert_close(&want, &got, "c")?;
            Ok(())
        });
    }

    #[test]
    fn hand_case_2x2() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        matmul_tiled(&a, &b, &mut c, 2, 2, 2, &TileConfig::westmere());
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn zero_dims_are_harmless() {
        let t = TileConfig::westmere();
        let mut c: Vec<f32> = Vec::new();
        matmul_tiled(&[], &[], &mut c, 0, 0, 0, &t);
        let mut c = vec![5.0f32; 3];
        // k = 0: C must still be zeroed (empty sum)
        matmul_tiled(&[], &[], &mut c, 1, 0, 3, &t);
        assert_eq!(c, vec![0.0; 3]);
    }

    #[test]
    fn packed_is_bit_identical_to_naive_on_every_tier() {
        // The tentpole contract: one accumulator per C element,
        // p-ascending mul/add, seeded from C — so the packed kernel
        // reproduces the naive i-j-p chain EXACTLY, for every blocking
        // and every runnable micro-kernel tier, on ragged shapes.
        check("matmul-packed-vs-naive", 30, |g| {
            let (m, k, n) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 40));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let t = rand_tiles(g);
            let mut want = vec![0.0f32; m * n];
            matmul_naive(&a, &b, &mut want, m, k, n);
            let pb = PackedPanel::pack(&b, k, n, t.kc.max(1));
            for tier in MicroKernel::supported() {
                let mut got = vec![0.0f32; m * n];
                matmul_acc_prepacked_with(tier, &a, &pb, &mut got, m,
                                          &t);
                if got != want {
                    return Err(format!(
                        "{} tier != naive at {m}x{k}x{n}, tiles {t:?}",
                        tier.name()));
                }
            }
            let mut got = vec![7.0f32; m * n]; // must be overwritten
            matmul_packed(&a, &b, &mut got, m, k, n, &t);
            if got != want {
                return Err(format!(
                    "matmul_packed != naive at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn packed_bits_do_not_depend_on_blocking() {
        // kc/mc splits only change which registers hold the chain, not
        // the chain itself: any two tile configs agree bitwise.
        check("matmul-packed-blocking-invariance", 15, |g| {
            let (m, k, n) =
                (g.usize_in(1, 33), g.usize_in(1, 48), g.usize_in(1, 33));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let (t1, t2) = (rand_tiles(g), rand_tiles(g));
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            matmul_packed(&a, &b, &mut c1, m, k, n, &t1);
            matmul_packed(&a, &b, &mut c2, m, k, n, &t2);
            if c1 != c2 {
                return Err(format!(
                    "blocking changed bits at {m}x{k}x{n}: {t1:?} vs \
                     {t2:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prepacked_panel_reuse_matches_fresh_pack() {
        // The reuse story: one PackedPanel serving several A operands
        // must give the same bits as packing per call.
        let mut g = Gen::new(9);
        let (k, n) = (37usize, 19usize);
        let b = g.f32_vec(k * n, 2.0);
        let t = TileConfig::westmere();
        let pb = PackedPanel::pack(&b, k, n, t.kc);
        for m in [1usize, 4, 13] {
            let a = g.f32_vec(m * k, 2.0);
            let mut fresh = vec![0.0f32; m * n];
            matmul_packed(&a, &b, &mut fresh, m, k, n, &t);
            let mut reused = vec![0.0f32; m * n];
            matmul_acc_prepacked(&a, &pb, &mut reused, m, &t);
            assert_eq!(fresh, reused, "reuse diverged at m={m}");
        }
    }

    #[test]
    fn packed_bias_matches_reference() {
        check("matmul-packed-bias", 15, |g| {
            let (m, k, n) =
                (g.usize_in(1, 20), g.usize_in(1, 20), g.usize_in(1, 20));
            let a = g.f32_vec(m * k, 2.0);
            let b = g.f32_vec(k * n, 2.0);
            let bias = g.f32_vec(n, 2.0);
            let t = rand_tiles(g);
            // bias-seeded naive chain: acc starts at bias[j]
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = bias[j];
                    for p in 0..k {
                        acc += a[i * k + p] * b[p * n + j];
                    }
                    want[i * n + j] = acc;
                }
            }
            let mut got = vec![0.0f32; m * n];
            matmul_bias_packed(&a, &b, &bias, &mut got, m, k, n, &t);
            if got != want {
                return Err(format!(
                    "packed bias != reference at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn packed_zero_dims_are_harmless() {
        let t = TileConfig::westmere();
        let mut c: Vec<f32> = Vec::new();
        matmul_packed(&[], &[], &mut c, 0, 0, 0, &t);
        let mut c = vec![5.0f32; 3];
        matmul_packed(&[], &[], &mut c, 1, 0, 3, &t);
        assert_eq!(c, vec![0.0; 3]);
        let mut c: Vec<f32> = Vec::new();
        matmul_packed(&[], &[1.0, 2.0], &mut c, 0, 1, 2, &t);
    }
}
