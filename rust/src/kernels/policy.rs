//! `ExecPolicy` — the one execution-policy knob for every kernel and
//! coordinator entry point.
//!
//! PR 2–5 grew three independent policy axes, each hand-threaded through
//! call chains as a bare parameter triple:
//!
//! * worker count (`--threads` → `LOCALITY_ML_THREADS` → all cores),
//! * macro-tile schedule (`--schedule` → `LOCALITY_ML_SCHEDULE` → auto),
//! * distance formulation (`--dist-algo` → `LOCALITY_ML_DIST_ALGO` →
//!   auto).
//!
//! [`ExecPolicy`] collapses the triple into one value with a builder;
//! [`ExecPolicy::resolve`] is the single point where the CLI/env
//! override layers are consulted. `Default` is fully-Auto: every field
//! defers to the session override chain, and whatever remains Auto
//! after resolution is decided per call from the work size (thread
//! gating via [`ExecPolicy::threads_for`], formulation via
//! [`ExecPolicy::algo_for`]).
//!
//! Policy invariants (unchanged from the per-parameter era, now stated
//! once): thread count and schedule NEVER change result bits — worker
//! partitions are output-disjoint or reduce in deterministic order —
//! and the formulation moves distances by ≤ 1e-4 (Exact is the
//! bit-stable oracle).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::distance::{self, DistanceAlgo};
use super::parallel::{self, Schedule};
use super::tile::TileConfig;

/// Execution policy: worker count, macro-tile schedule, and distance
/// formulation. `threads == 0` means "session default / auto".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecPolicy {
    /// Worker count for the parallel macro-tile layer; 0 = resolve
    /// from `--threads` → `LOCALITY_ML_THREADS` → available cores,
    /// 1 = the exact sequential kernels.
    pub threads: usize,
    /// Macro-tile scheduling policy; `Auto` = resolve from
    /// `--schedule` → `LOCALITY_ML_SCHEDULE`, then per-call heuristic.
    pub schedule: Schedule,
    /// Distance formulation; `Auto` = resolve from `--dist-algo` →
    /// `LOCALITY_ML_DIST_ALGO`, then per-call multiply-add count.
    pub algo: DistanceAlgo,
}

impl Default for ExecPolicy {
    /// Fully-Auto: every axis defers to the session override chain.
    fn default() -> Self {
        Self {
            threads: 0,
            schedule: Schedule::Auto,
            algo: DistanceAlgo::Auto,
        }
    }
}

impl ExecPolicy {
    /// The fully-Auto policy (same as `Default`).
    pub fn auto() -> Self {
        Self::default()
    }

    /// The exact sequential policy: one thread, static schedule, Exact
    /// distances — bit-identical to the PR-1 kernels by construction.
    pub fn sequential() -> Self {
        Self {
            threads: 1,
            schedule: Schedule::Static,
            algo: DistanceAlgo::Exact,
        }
    }

    /// Builder: pin the worker count (0 restores auto).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Builder: pin the macro-tile schedule.
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Builder: pin the distance formulation.
    pub fn with_algo(mut self, algo: DistanceAlgo) -> Self {
        self.algo = algo;
        self
    }

    /// THE resolution point: consult the CLI→env→default chain once
    /// for every still-Auto axis. After this, `threads >= 1`;
    /// `schedule`/`algo` may legitimately remain `Auto`, meaning "no
    /// session override — decide per call from the work size".
    pub fn resolve(&self) -> Self {
        Self {
            threads: if self.threads == 0 {
                parallel::default_threads()
            } else {
                self.threads
            },
            schedule: match self.schedule {
                Schedule::Auto => parallel::default_schedule(),
                s => s,
            },
            algo: match self.algo {
                DistanceAlgo::Auto => distance::default_dist_algo(),
                a => a,
            },
        }
    }

    /// Worker count for a job of `work` multiply-adds: the resolved
    /// thread count, gated so sub-`MIN_PAR_WORK` jobs stay on the
    /// sequential kernel (spawn/join would cost more than it saves).
    pub fn threads_for(&self, work: usize) -> usize {
        let t = if self.threads == 0 {
            parallel::default_threads()
        } else {
            self.threads
        };
        parallel::effective_threads(t, work)
    }

    /// Distance formulation for a job of `work` multiply-adds: the
    /// resolved algo, with a still-Auto choice decided by work size.
    pub fn algo_for(&self, work: usize) -> DistanceAlgo {
        match self.algo {
            DistanceAlgo::Auto => distance::default_dist_algo(),
            a => a,
        }
        .resolve(work)
    }
}

/// Default micro-batch size for the resident serving engine: large
/// enough that a full batch amortizes one pass over the resident train
/// tiles (the fused joint scan's reuse window), small enough that the
/// coalescing delay stays in the microsecond regime.
pub const DEFAULT_MAX_BATCH: usize = 64;
/// Default admission-queue coalescing window in microseconds: how long
/// the oldest queued query may wait for the batch to fill before the
/// batcher flushes a partial batch.
pub const DEFAULT_MAX_WAIT_US: u64 = 2_000;
/// Default bound on the admission queue. Once this many queries are
/// pending, further arrivals are shed with an explicit `overloaded`
/// reply instead of growing the queue without limit.
pub const DEFAULT_QUEUE_CAP: usize = 1_024;

/// The serving-engine policy knobs — the micro-batching counterpart of
/// [`ExecPolicy`].
///
/// Where [`ExecPolicy`] decides *how* a batch executes (threads,
/// schedule, distance formulation), `ServePolicy` decides *when* a
/// batch forms: how many queries coalesce into one pass over the
/// resident train tiles (`max_batch`), how long the oldest query may
/// wait for co-travellers (`max_wait_us`), and how deep the admission
/// queue may grow before load is shed (`queue_cap`).
///
/// Resolution mirrors the execution axes: every still-Auto field
/// defers to its `LOCALITY_ML_*` environment variable, then to the
/// compiled default — [`ServePolicy::resolve`] is the single point
/// where that chain is consulted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServePolicy {
    /// Flush a batch as soon as this many queries are pending;
    /// `0` = resolve from `LOCALITY_ML_MAX_BATCH` →
    /// [`DEFAULT_MAX_BATCH`]. `1` disables coalescing (every query
    /// dispatches alone — the latency-over-throughput extreme).
    pub max_batch: usize,
    /// Flush a partial batch once the *oldest* pending query has
    /// waited this many microseconds; `u64::MAX` = resolve from
    /// `LOCALITY_ML_MAX_WAIT_US` → [`DEFAULT_MAX_WAIT_US`]. `0` is a
    /// legitimate pinned value: flush on the next poll, never hold a
    /// query back.
    pub max_wait_us: u64,
    /// Shed arrivals once this many queries are pending; `0` = resolve
    /// from `LOCALITY_ML_QUEUE_CAP` → [`DEFAULT_QUEUE_CAP`]. Resolved
    /// values are clamped to at least `max_batch` so a full batch can
    /// always form.
    pub queue_cap: usize,
}

impl Default for ServePolicy {
    /// Fully-Auto: every knob defers to the env-override chain.
    fn default() -> Self {
        Self { max_batch: 0, max_wait_us: u64::MAX, queue_cap: 0 }
    }
}

impl ServePolicy {
    /// The fully-Auto policy (same as `Default`).
    pub fn auto() -> Self {
        Self::default()
    }

    /// Builder: pin the batch size (0 restores auto).
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Builder: pin the coalescing window (`u64::MAX` restores auto).
    pub fn with_max_wait_us(mut self, max_wait_us: u64) -> Self {
        self.max_wait_us = max_wait_us;
        self
    }

    /// Builder: pin the admission-queue bound (0 restores auto).
    pub fn with_queue_cap(mut self, queue_cap: usize) -> Self {
        self.queue_cap = queue_cap;
        self
    }

    /// THE resolution point for the serving knobs: consult the
    /// CLI→env→default chain once per still-Auto field. After this
    /// `max_batch >= 1`, `max_wait_us` is finite and
    /// `queue_cap >= max_batch`.
    pub fn resolve(&self) -> Self {
        let max_batch = if self.max_batch == 0 {
            env_usize("LOCALITY_ML_MAX_BATCH")
                .unwrap_or(DEFAULT_MAX_BATCH)
                .max(1)
        } else {
            self.max_batch
        };
        let max_wait_us = if self.max_wait_us == u64::MAX {
            env_u64("LOCALITY_ML_MAX_WAIT_US")
                .unwrap_or(DEFAULT_MAX_WAIT_US)
        } else {
            self.max_wait_us
        };
        let queue_cap = if self.queue_cap == 0 {
            env_usize("LOCALITY_ML_QUEUE_CAP").unwrap_or(DEFAULT_QUEUE_CAP)
        } else {
            self.queue_cap
        };
        Self {
            max_batch,
            max_wait_us,
            // a cap below the batch size could never fill a batch; the
            // clamp keeps the two knobs independently settable
            queue_cap: queue_cap.max(max_batch),
        }
    }
}

/// Session-wide `--chunk-rows` override for the out-of-core train
/// store; 0 = unset (fall through to the env/auto chain).
static CHUNK_ROWS_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin (or with `None` clear) the session-wide chunk size, in train
/// rows, for newly written `.lmtc` stores — the `--chunk-rows` CLI
/// layer of the [`default_chunk_rows`] resolution chain.
pub fn set_chunk_rows(rows: Option<usize>) {
    CHUNK_ROWS_OVERRIDE.store(rows.unwrap_or(0), Ordering::Relaxed);
}

/// Chunk size (train rows per feature chunk) for the out-of-core
/// store, resolved through the same override chain as every other
/// execution knob: `--chunk-rows` → `LOCALITY_ML_CHUNK_ROWS` → an auto
/// size of ~4 MiB of f32 features per chunk (two in flight under the
/// double buffer ≈ 8 MiB working set), never smaller than one train
/// macro-tile of the fused scans' blocking
/// ([`TileConfig::pair_tiles`]) so a chunk always covers at least one
/// full reuse window.
pub fn default_chunk_rows(d: usize, tiles: &TileConfig) -> usize {
    let pinned = CHUNK_ROWS_OVERRIDE.load(Ordering::Relaxed);
    if pinned > 0 {
        return pinned;
    }
    if let Some(v) = env_usize("LOCALITY_ML_CHUNK_ROWS") {
        if v > 0 {
            return v;
        }
    }
    let (_, jt) = tiles.pair_tiles(d);
    ((1 << 20) / d.max(1)).max(jt).max(1)
}

/// Default bound on chunk-read attempts for transient store faults:
/// the first read plus two retries — enough to ride out an
/// `EINTR`-class blip, bounded so a persistently failing disk surfaces
/// as a typed error instead of an unbounded retry loop.
pub const DEFAULT_RETRY_ATTEMPTS: u32 = 3;
/// Default microseconds slept between transient-fault retries.
pub const DEFAULT_RETRY_BACKOFF_US: u64 = 100;

/// Bounded retry policy for transient faults on the chunked store's
/// read path — the recovery half of the out-of-core failure domain
/// (`data/store.rs`). Corrupt/truncated chunks are **never** retried
/// (a checksum mismatch on re-read of a bad disk block is not
/// transient); only `Transient`-class errors consult this policy.
///
/// Resolution mirrors [`ExecPolicy`] / [`ServePolicy`]: still-Auto
/// fields defer to the session override (`--retry-attempts` /
/// `--retry-backoff-us`), then the `LOCALITY_ML_RETRY_ATTEMPTS` /
/// `LOCALITY_ML_RETRY_BACKOFF_US` environment, then the compiled
/// defaults. [`RetryPolicy::resolve`] is the single consultation
/// point, called once per store open.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum read attempts per chunk (first try included);
    /// `0` = resolve from the override chain. Resolved values are
    /// clamped to at least 1.
    pub max_attempts: u32,
    /// Microseconds slept between attempts; `u64::MAX` = resolve from
    /// the override chain. `0` is a legitimate pinned value (retry
    /// immediately — what the fault property suite uses to stay fast).
    pub backoff_us: u64,
}

impl Default for RetryPolicy {
    /// Fully-Auto: both knobs defer to the override chain.
    fn default() -> Self {
        Self { max_attempts: 0, backoff_us: u64::MAX }
    }
}

impl RetryPolicy {
    /// The fully-Auto policy (same as `Default`).
    pub fn auto() -> Self {
        Self::default()
    }

    /// Builder: pin the attempt bound (0 restores auto).
    pub fn with_attempts(mut self, max_attempts: u32) -> Self {
        self.max_attempts = max_attempts;
        self
    }

    /// Builder: pin the backoff (`u64::MAX` restores auto).
    pub fn with_backoff_us(mut self, backoff_us: u64) -> Self {
        self.backoff_us = backoff_us;
        self
    }

    /// THE resolution point for the retry knobs: consult the
    /// CLI→env→default chain once per still-Auto field. After this
    /// `max_attempts >= 1` and `backoff_us` is finite.
    pub fn resolve(&self) -> Self {
        let max_attempts = if self.max_attempts == 0 {
            let session = RETRY_ATTEMPTS_OVERRIDE.load(Ordering::Relaxed);
            if session > 0 {
                session
            } else {
                env_u32("LOCALITY_ML_RETRY_ATTEMPTS")
                    .filter(|&v| v > 0)
                    .unwrap_or(DEFAULT_RETRY_ATTEMPTS)
            }
        } else {
            self.max_attempts
        };
        let backoff_us = if self.backoff_us == u64::MAX {
            let session = RETRY_BACKOFF_OVERRIDE.load(Ordering::Relaxed);
            if session != u64::MAX {
                session
            } else {
                env_u64("LOCALITY_ML_RETRY_BACKOFF_US")
                    .unwrap_or(DEFAULT_RETRY_BACKOFF_US)
            }
        } else {
            self.backoff_us
        };
        Self { max_attempts: max_attempts.max(1), backoff_us }
    }
}

/// Session-wide `--retry-attempts` override; 0 = unset.
static RETRY_ATTEMPTS_OVERRIDE: AtomicU32 = AtomicU32::new(0);
/// Session-wide `--retry-backoff-us` override; `u64::MAX` = unset
/// (0 is a legitimate pinned backoff).
static RETRY_BACKOFF_OVERRIDE: AtomicU64 = AtomicU64::new(u64::MAX);

/// Pin (or with `None` clear) the session-wide transient-retry attempt
/// bound — the `--retry-attempts` CLI layer of the [`RetryPolicy`]
/// resolution chain.
pub fn set_retry_attempts(attempts: Option<u32>) {
    RETRY_ATTEMPTS_OVERRIDE.store(attempts.unwrap_or(0),
                                  Ordering::Relaxed);
}

/// Pin (or with `None` clear) the session-wide transient-retry backoff
/// in microseconds — the `--retry-backoff-us` CLI layer of the
/// [`RetryPolicy`] resolution chain.
pub fn set_retry_backoff_us(backoff_us: Option<u64>) {
    RETRY_BACKOFF_OVERRIDE.store(backoff_us.unwrap_or(u64::MAX),
                                 Ordering::Relaxed);
}

/// Session-wide `--fault-spec` override; `None` = unset (fall through
/// to the env chain), `Some("")` = explicitly off.
static FAULT_SPEC_OVERRIDE: Mutex<Option<String>> = Mutex::new(None);

/// Pin (or with `None` clear) the session-wide fault-injection spec —
/// the `--fault-spec` CLI layer of the [`default_fault_spec`] chain.
/// Passing `Some(String::new())` pins injection explicitly *off*,
/// shadowing any `LOCALITY_ML_FAULT_SPEC` in the environment.
pub fn set_fault_spec(spec: Option<String>) {
    let mut guard = FAULT_SPEC_OVERRIDE
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    *guard = spec;
}

/// The fault-injection spec for newly opened chunked stores, resolved
/// through the usual chain: `--fault-spec` →
/// `LOCALITY_ML_FAULT_SPEC` → `None` (injection off — the production
/// default; the store then carries no injector and the scan's fault
/// check is a single `Option` test). The spec grammar lives in
/// `data/faults.rs`.
pub fn default_fault_spec() -> Option<String> {
    {
        let guard = FAULT_SPEC_OVERRIDE
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        if let Some(spec) = guard.as_ref() {
            if spec.is_empty() {
                return None;
            }
            return Some(spec.clone());
        }
    }
    match std::env::var("LOCALITY_ML_FAULT_SPEC") {
        Ok(spec) if !spec.is_empty() => Some(spec),
        _ => None,
    }
}

/// Parse an environment variable as `usize`, ignoring unset or
/// unparsable values (mirroring the threads/schedule/dist-algo
/// policies).
fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Parse an environment variable as `u64`, ignoring unset or
/// unparsable values.
fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Parse an environment variable as `u32`, ignoring unset or
/// unparsable values.
fn env_u32(name: &str) -> Option<u32> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_fully_auto() {
        let p = ExecPolicy::default();
        assert_eq!(p.threads, 0);
        assert_eq!(p.schedule, Schedule::Auto);
        assert_eq!(p.algo, DistanceAlgo::Auto);
        assert_eq!(p, ExecPolicy::auto());
    }

    #[test]
    fn sequential_is_the_exact_policy() {
        let p = ExecPolicy::sequential();
        assert_eq!(p.threads, 1);
        assert_eq!(p.schedule, Schedule::Static);
        assert_eq!(p.algo, DistanceAlgo::Exact);
        // resolve() must not disturb pinned fields
        assert_eq!(p.resolve(), p);
    }

    #[test]
    fn builder_pins_fields() {
        let p = ExecPolicy::auto()
            .with_threads(3)
            .with_schedule(Schedule::Stealing)
            .with_algo(DistanceAlgo::Gemm);
        assert_eq!(p.threads, 3);
        assert_eq!(p.schedule, Schedule::Stealing);
        assert_eq!(p.algo, DistanceAlgo::Gemm);
        assert_eq!(p.with_threads(0).resolve().schedule,
                   Schedule::Stealing);
    }

    #[test]
    fn resolve_fills_auto_threads() {
        let r = ExecPolicy::auto().resolve();
        assert!(r.threads >= 1, "resolved threads must be >= 1");
        // pinned threads pass through untouched
        assert_eq!(ExecPolicy::auto().with_threads(7).resolve().threads,
                   7);
    }

    #[test]
    fn threads_for_gates_small_work() {
        let p = ExecPolicy::auto().with_threads(8);
        assert_eq!(p.threads_for(16), 1,
            "tiny jobs must stay sequential");
        assert_eq!(p.threads_for(usize::MAX / 2), 8);
        // explicit 1 stays 1 at any size
        assert_eq!(ExecPolicy::sequential().threads_for(usize::MAX / 2),
                   1);
    }

    #[test]
    fn chunk_rows_resolution_chain_and_auto_floor() {
        let tiles = TileConfig::westmere();
        // auto: ~4 MiB of f32 features, never below one train tile
        let (_, jt) = tiles.pair_tiles(8);
        let auto = default_chunk_rows(8, &tiles);
        assert_eq!(auto, ((1usize << 20) / 8).max(jt));
        // huge d drives the byte target below one tile; the tile floor
        // (and the >= 1 floor) must hold
        assert!(default_chunk_rows(1 << 24, &tiles) >= 1);
        // a pinned override wins over the auto heuristic...
        set_chunk_rows(Some(37));
        assert_eq!(default_chunk_rows(8, &tiles), 37);
        // ...and clearing it restores the auto chain
        set_chunk_rows(None);
        assert_eq!(default_chunk_rows(8, &tiles), auto);
        // LOCALITY_ML_CHUNK_ROWS=0 must never produce a zero chunk
        // size (a zero-row chunk loop can't make progress): the env
        // layer ignores it and falls through to auto. The CLI layer
        // rejects 0 before it gets here (`--chunk-rows must be >= 1`,
        // regression-tested in the integration suite); at the setter
        // level Some(0) is the documented "clear" sentinel.
        std::env::set_var("LOCALITY_ML_CHUNK_ROWS", "0");
        assert_eq!(default_chunk_rows(8, &tiles), auto,
            "env chunk-rows 0 must fall through to the auto size");
        std::env::set_var("LOCALITY_ML_CHUNK_ROWS", "41");
        assert_eq!(default_chunk_rows(8, &tiles), 41);
        // the session override outranks the env
        set_chunk_rows(Some(37));
        assert_eq!(default_chunk_rows(8, &tiles), 37);
        set_chunk_rows(Some(0)); // sentinel: same as clearing
        assert_eq!(default_chunk_rows(8, &tiles), 41);
        std::env::remove_var("LOCALITY_ML_CHUNK_ROWS");
        assert_eq!(default_chunk_rows(8, &tiles), auto);
    }

    #[test]
    fn retry_policy_resolution_chain() {
        // compiled defaults
        let r = RetryPolicy::auto().resolve();
        assert_eq!(r.max_attempts, DEFAULT_RETRY_ATTEMPTS);
        assert_eq!(r.backoff_us, DEFAULT_RETRY_BACKOFF_US);
        // pinned fields pass through, zero-attempt clamps to 1
        let p = RetryPolicy::auto().with_attempts(5).with_backoff_us(0);
        assert_eq!(p.resolve(), p);
        assert_eq!(p.resolve().backoff_us, 0,
            "0 backoff is a legitimate pinned value");
        // env layer (this test is the only reader/writer of these vars)
        std::env::set_var("LOCALITY_ML_RETRY_ATTEMPTS", "7");
        std::env::set_var("LOCALITY_ML_RETRY_BACKOFF_US", "9");
        let r = RetryPolicy::auto().resolve();
        assert_eq!((r.max_attempts, r.backoff_us), (7, 9));
        // env 0 attempts would disable reading entirely; ignored
        std::env::set_var("LOCALITY_ML_RETRY_ATTEMPTS", "0");
        assert_eq!(RetryPolicy::auto().resolve().max_attempts,
                   DEFAULT_RETRY_ATTEMPTS);
        // session override (the --retry-* CLI layer) outranks the env
        set_retry_attempts(Some(2));
        set_retry_backoff_us(Some(4));
        let r = RetryPolicy::auto().resolve();
        assert_eq!((r.max_attempts, r.backoff_us), (2, 4));
        set_retry_attempts(None);
        set_retry_backoff_us(None);
        std::env::remove_var("LOCALITY_ML_RETRY_ATTEMPTS");
        std::env::remove_var("LOCALITY_ML_RETRY_BACKOFF_US");
        let r = RetryPolicy::auto().resolve();
        assert_eq!(r.max_attempts, DEFAULT_RETRY_ATTEMPTS);
        assert_eq!(r.backoff_us, DEFAULT_RETRY_BACKOFF_US);
    }

    #[test]
    fn fault_spec_session_override_resolution() {
        // Production default: no spec, injection off. (The env layer
        // is exercised by the CI fault-matrix job, which runs whole
        // suites under LOCALITY_ML_FAULT_SPEC; reading the raw env
        // here would race with it, so this test only pins the
        // session-override layer above it.)
        set_fault_spec(Some("seed=1,transient=30".into()));
        assert_eq!(default_fault_spec().as_deref(),
                   Some("seed=1,transient=30"));
        // empty spec pins injection explicitly OFF (shadows any env)
        set_fault_spec(Some(String::new()));
        assert_eq!(default_fault_spec(), None);
        set_fault_spec(None);
    }

    #[test]
    fn algo_for_resolves_pinned_and_auto() {
        let huge = 1 << 30;
        assert_eq!(
            ExecPolicy::auto().with_algo(DistanceAlgo::Exact)
                .algo_for(huge),
            DistanceAlgo::Exact);
        assert_eq!(
            ExecPolicy::auto().with_algo(DistanceAlgo::Gemm).algo_for(0),
            DistanceAlgo::Gemm);
        // Auto resolves to a concrete formulation, never Auto itself
        let got = ExecPolicy::auto().algo_for(huge);
        assert!(got == DistanceAlgo::Exact || got == DistanceAlgo::Gemm,
            "algo_for left Auto unresolved: {got:?}");
    }
}
