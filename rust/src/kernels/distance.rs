//! Cache-blocked pairwise squared Euclidean distances — the shared hot
//! loop of k-NN (Alg 10) and the Parzen–Rosenblatt window (Alg 11).
//!
//! The naive scan streams the whole training matrix through the cache
//! once **per query**: for `|RT|` training rows of `d` features, every
//! query re-reads `|RT|·d` elements whose reuse distance exceeds any
//! cache level (§4 of the paper measures exactly this). The tiled kernel
//! blocks both sides: a train tile and a query tile sized by
//! [`TileConfig::pair_tiles`] fit the L1 budget together, so each train
//! row loaded from memory is reused against a whole tile of queries.
//!
//! Per-pair arithmetic (one pass over `d`, subtract–square–accumulate)
//! is identical in both versions, so tiled distances are bit-identical
//! to naive ones and prediction parity downstream is exact, not just
//! within tolerance.

use super::tile::TileConfig;

/// Squared Euclidean distance, accumulated in ascending feature order.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Gather `idx` rows of a row-major `[n × d]` matrix into one
/// contiguous buffer. Index-sliced consumers (CV splits, bootstrap
/// samples) pay this one streaming copy so the tiled kernels downstream
/// see unit-stride rows — the §3.3.1 layout guideline applied to
/// scattered row sets.
pub fn gather_rows(src: &[f32], d: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&src[i * d..(i + 1) * d]);
    }
    out
}

/// Naive reference: `out[q·n + j] = ‖queries[q] − train[j]‖²`, computed
/// query-at-a-time (each query streams the full training matrix).
pub fn pairwise_sq_dists_naive(
    train: &[f32],
    queries: &[f32],
    d: usize,
    out: &mut [f32],
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(out.len(), nq * n);
    for q in 0..nq {
        let qrow = &queries[q * d..(q + 1) * d];
        for j in 0..n {
            out[q * n + j] = sq_dist(qrow, &train[j * d..(j + 1) * d]);
        }
    }
}

/// Cache-blocked pairwise distances: train/query row tiles sized from
/// the cache model so the train tile is L1-resident across the query
/// tile. Bit-identical to [`pairwise_sq_dists_naive`].
pub fn pairwise_sq_dists_tiled(
    train: &[f32],
    queries: &[f32],
    d: usize,
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(out.len(), nq * n);
    let (qt, jt) = t.pair_tiles(d);
    for q0 in (0..nq).step_by(qt) {
        let qhi = (q0 + qt).min(nq);
        for j0 in (0..n).step_by(jt) {
            let jhi = (j0 + jt).min(n);
            for q in q0..qhi {
                let qrow = &queries[q * d..(q + 1) * d];
                for j in j0..jhi {
                    out[q * n + j] =
                        sq_dist(qrow, &train[j * d..(j + 1) * d]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn hand_case() {
        let train = [0.0, 0.0, 3.0, 4.0]; // two 2-d points
        let queries = [0.0, 0.0];
        let mut out = [0.0f32; 2];
        pairwise_sq_dists_tiled(&train, &queries, 2, &mut out,
                                &TileConfig::westmere());
        assert_eq!(out, [0.0, 25.0]);
    }

    #[test]
    fn gather_rows_selects_rows_in_index_order() {
        let src = [0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0];
        assert_eq!(gather_rows(&src, 2, &[2, 0, 2]),
                   vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
        assert!(gather_rows(&src, 2, &[]).is_empty());
    }

    #[test]
    fn tiled_is_bit_identical_to_naive() {
        check("pairwise-tiled-vs-naive", 30, |g| {
            let d = g.usize_in(1, 24);
            let n = g.usize_in(0, 50);
            let nq = g.usize_in(0, 20);
            let train = g.f32_vec(n * d, 3.0);
            let queries = g.f32_vec(nq * d, 3.0);
            // tiny tiles to force ragged edges
            let t = TileConfig {
                mc: 1,
                kc: 1,
                nc: 1,
                l1_f32: g.usize_in(2, 64) * d,
            };
            let mut want = vec![0.0f32; nq * n];
            let mut got = vec![-1.0f32; nq * n];
            pairwise_sq_dists_naive(&train, &queries, d, &mut want);
            pairwise_sq_dists_tiled(&train, &queries, d, &mut got, &t);
            prop_assert!(want == got, "tiled distances diverged");
            Ok(())
        });
    }

}
