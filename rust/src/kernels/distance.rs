//! Cache-blocked pairwise squared Euclidean distances — the shared hot
//! loop of k-NN (Alg 10) and the Parzen–Rosenblatt window (Alg 11) — in
//! **two formulations** selected by [`DistanceAlgo`]:
//!
//! * **Exact** — one pass over `d` per pair, subtract–square–accumulate
//!   ([`sq_dist`]). The naive scan streams the whole training matrix
//!   through the cache once **per query** (§4 of the paper measures
//!   exactly this); the tiled kernel blocks both sides so a train tile
//!   is L1-resident across a whole query tile. Per-pair arithmetic is
//!   identical in both versions, so tiled distances are bit-identical
//!   to naive ones and prediction parity downstream is exact.
//! * **Gemm** — the §4 "reuse of computation results" decomposition
//!   `‖q−t‖² = ‖q‖² + ‖t‖² − 2·q·t`: the dominant cross term becomes a
//!   plain GEMM over the pre-transposed training matrix, executed by
//!   the **packed SIMD micro-kernel** ([`matmul_packed`]) — the
//!   training operand is packed once into reuse-ordered, 32-byte
//!   aligned [`PackedPanel`]s and streamed through the register-blocked
//!   scalar/SSE2/AVX2 kernel (the blocking the `bench_pack` CI gate
//!   measures at ≥ 2× over the tiled-scalar loop) — while the row norms
//!   are **precomputed once** and reused across every query, every CV
//!   split, every sweep candidate and every ensemble member
//!   ([`NormCache`]). Results are within ≤ 1e-4 of Exact on well-scaled
//!   data (property-tested) but NOT bit-identical: the formulation
//!   reassociates the reduction. Exact stays the oracle. (The packed
//!   matmul itself is bit-identical to the naive matmul at every SIMD
//!   tier, so the Gemm distances do not depend on blocking, thread
//!   count, or the dispatched tier — only the *formulation* moves
//!   bits.)
//!
//! # Catastrophic cancellation guard
//!
//! When `q ≈ t` (near-duplicate rows) or the feature magnitudes are
//! large, `‖q‖² + ‖t‖² − 2·q·t` cancels catastrophically and can come
//! out a few ulps **negative** — a downstream `sqrt` or Gaussian
//! `exp(−d/2h²)` bandwidth pass would turn that into NaN. Every Gemm
//! distance is therefore clamped at `0.0` before it leaves the kernel
//! (regression-tested on near-duplicate, constant-feature and
//! large-magnitude rows). The Gemm formulation assumes finite features;
//! non-finite inputs (±inf/NaN) stay on the Exact path, whose NaN
//! ordering contract is preserved by `total_cmp` downstream.
//!
//! [`matmul_packed`]: super::matmul::matmul_packed
//! [`PackedPanel`]: super::pack::PackedPanel

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::matmul::matmul_acc_prepacked;
use super::pack::PackedPanel;
use super::tile::TileConfig;

/// Squared Euclidean distance, accumulated in ascending feature order.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Gather `idx` rows of a row-major `[n × d]` matrix into one
/// contiguous buffer. Index-sliced consumers (CV splits, bootstrap
/// samples) pay this one streaming copy so the tiled kernels downstream
/// see unit-stride rows — the §3.3.1 layout guideline applied to
/// scattered row sets.
pub fn gather_rows(src: &[f32], d: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&src[i * d..(i + 1) * d]);
    }
    out
}

// ---------------------------------------------------------------------
// DistanceAlgo policy
// ---------------------------------------------------------------------

/// Which distance formulation a call should use. Mirrors the
/// threads/schedule policies: an explicit CLI/env choice is taken
/// verbatim, `Auto` picks per call by the work size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistanceAlgo {
    /// Subtract–square–accumulate per pair — the bit-stable oracle
    /// (and the only formulation defined for non-finite features).
    Exact,
    /// `‖q‖² + ‖t‖² − 2·q·t` with the cross term as a GEMM over cached
    /// row norms; ≤ 1e-4 vs Exact on finite data, clamped at 0.
    Gemm,
    /// Gemm when the call's multiply-adds clear [`MIN_GEMM_WORK`]
    /// (the transpose + norm-combine overhead amortises), else Exact.
    Auto,
}

/// Minimum distance-kernel work (f32 multiply-adds, `nq·n·d`) before
/// the Gemm formulation's packing overhead (one train transpose, one
/// norm-combine pass over the `nq × n` output) pays for itself under
/// [`DistanceAlgo::Auto`]. Below this the Exact tiled kernel wins.
pub const MIN_GEMM_WORK: usize = 1 << 18;

impl DistanceAlgo {
    /// Parse a CLI/env spelling. Accepts `exact`, `gemm` and `auto`,
    /// case-insensitively.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "exact" => Some(Self::Exact),
            "gemm" => Some(Self::Gemm),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    /// Canonical spelling (the one `parse` round-trips).
    pub fn name(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Gemm => "gemm",
            Self::Auto => "auto",
        }
    }

    /// Resolve `Auto` against a call's multiply-add count; explicit
    /// policies pass through verbatim. Never returns `Auto`, so
    /// dispatch sites can match on the concrete formulation.
    pub fn resolve(self, work: usize) -> Self {
        match self {
            Self::Auto => {
                if work >= MIN_GEMM_WORK {
                    Self::Gemm
                } else {
                    Self::Exact
                }
            }
            other => other,
        }
    }
}

/// Session-wide `--dist-algo` override; 0 = unset, then 1/2/3 for
/// exact/gemm/auto (the encoding is private to this pair of fns).
static DIST_ALGO_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install the `--dist-algo` CLI override for the rest of the process
/// (`None` clears it).
pub fn set_dist_algo(algo: Option<DistanceAlgo>) {
    let code = match algo {
        None => 0,
        Some(DistanceAlgo::Exact) => 1,
        Some(DistanceAlgo::Gemm) => 2,
        Some(DistanceAlgo::Auto) => 3,
    };
    DIST_ALGO_OVERRIDE.store(code, Ordering::Relaxed);
}

/// Resolve the session distance formulation: CLI override
/// ([`set_dist_algo`]) → `LOCALITY_ML_DIST_ALGO` (unparsable values are
/// ignored, mirroring the threads/schedule policies) →
/// [`DistanceAlgo::Auto`].
pub fn default_dist_algo() -> DistanceAlgo {
    match DIST_ALGO_OVERRIDE.load(Ordering::Relaxed) {
        1 => return DistanceAlgo::Exact,
        2 => return DistanceAlgo::Gemm,
        3 => return DistanceAlgo::Auto,
        _ => {}
    }
    if let Ok(v) = std::env::var("LOCALITY_ML_DIST_ALGO") {
        if let Some(a) = DistanceAlgo::parse(&v) {
            return a;
        }
    }
    DistanceAlgo::Auto
}

// ---------------------------------------------------------------------
// Cached row norms
// ---------------------------------------------------------------------

thread_local! {
    /// Per-thread count of [`NormCache::compute`] calls — the
    /// instrumentation behind the "norms are computed once per dataset"
    /// reuse property tests (thread-local so concurrent tests cannot
    /// perturb each other's counts; at `threads = 1` every sweep job
    /// runs inline on the caller's thread, so a hidden per-split
    /// rebuild lands on the caller's counter and the test catches it).
    static NORM_CACHE_BUILDS: Cell<u64> = Cell::new(0);
}

/// This thread's running [`NormCache::compute`] count (see the
/// thread-local's doc for how the reuse property tests consume it).
pub fn norm_cache_builds() -> u64 {
    NORM_CACHE_BUILDS.with(|c| c.get())
}

/// `‖row‖²` for every row of a row-major `[n × d]` matrix, accumulated
/// in ascending feature order (bitwise, this is `sq_dist(row, zeros)`).
pub fn row_sq_norms(rows: &[f32], d: usize) -> Vec<f32> {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(rows.len() % d, 0);
    rows.chunks_exact(d)
        .map(|r| {
            let mut acc = 0.0f32;
            for &v in r {
                acc += v * v;
            }
            acc
        })
        .collect()
}

/// Precomputed `‖row‖²` per dataset row — the "reuse of computation
/// results" half of the Gemm formulation. Built **once per dataset**
/// and shared (by reference) across every CV split, every sweep
/// candidate and every ensemble member; index-sliced consumers
/// [`gather`](NormCache::gather) the subset they need instead of ever
/// recomputing a norm.
#[derive(Debug, Clone)]
pub struct NormCache {
    norms: Vec<f32>,
}

impl NormCache {
    /// Compute the per-row squared norms of a row-major `[n × d]`
    /// matrix (counted on [`norm_cache_builds`] — the reuse property
    /// tests assert this happens once per dataset, not once per split).
    pub fn compute(rows: &[f32], d: usize) -> Self {
        NORM_CACHE_BUILDS.with(|c| c.set(c.get() + 1));
        Self { norms: row_sq_norms(rows, d) }
    }

    /// Wrap already-materialised per-row norms — e.g. the norms block
    /// of a chunked `.lmtc` train store, persisted at conversion time
    /// from the same ascending accumulation as [`NormCache::compute`].
    /// A *load*, not a *build*: [`norm_cache_builds`] does not move, so
    /// the once-per-dataset reuse tests keep their exact counts on the
    /// out-of-core path too.
    pub fn from_norms(norms: Vec<f32>) -> Self {
        Self { norms }
    }

    /// The cached norms, indexed by dataset row.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Number of cached rows.
    pub fn len(&self) -> usize {
        self.norms.len()
    }

    /// True when no rows are cached.
    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }

    /// Norms of an index-sliced row subset (CV split, bootstrap sample)
    /// — one gather, no recomputation.
    pub fn gather(&self, idx: &[usize]) -> Vec<f32> {
        idx.iter().map(|&i| self.norms[i]).collect()
    }
}

/// Transpose a row-major `[n × d]` matrix into `[d × n]` — the one-time
/// packing step that lets the Gemm cross term run as a plain
/// `[nq × d]·[d × n]` matmul with unit-stride inner rows.
pub fn transpose_rows(rows: &[f32], d: usize) -> Vec<f32> {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(rows.len() % d, 0);
    let n = rows.len() / d;
    let mut out = vec![0.0f32; rows.len()];
    for i in 0..n {
        let row = &rows[i * d..(i + 1) * d];
        for (f, &v) in row.iter().enumerate() {
            out[f * n + i] = v;
        }
    }
    out
}

// ---------------------------------------------------------------------
// Exact kernels
// ---------------------------------------------------------------------

/// Naive reference: `out[q·n + j] = ‖queries[q] − train[j]‖²`, computed
/// query-at-a-time (each query streams the full training matrix).
pub fn pairwise_sq_dists_naive(
    train: &[f32],
    queries: &[f32],
    d: usize,
    out: &mut [f32],
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(out.len(), nq * n);
    for q in 0..nq {
        let qrow = &queries[q * d..(q + 1) * d];
        for j in 0..n {
            out[q * n + j] = sq_dist(qrow, &train[j * d..(j + 1) * d]);
        }
    }
}

/// Cache-blocked pairwise distances: train/query row tiles sized from
/// the cache model so the train tile is L1-resident across the query
/// tile. Bit-identical to [`pairwise_sq_dists_naive`].
pub fn pairwise_sq_dists_tiled(
    train: &[f32],
    queries: &[f32],
    d: usize,
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    assert_eq!(queries.len() % d, 0);
    let n = train.len() / d;
    let nq = queries.len() / d;
    assert_eq!(out.len(), nq * n);
    let (qt, jt) = t.pair_tiles(d);
    for q0 in (0..nq).step_by(qt) {
        let qhi = (q0 + qt).min(nq);
        for j0 in (0..n).step_by(jt) {
            let jhi = (j0 + jt).min(n);
            for q in q0..qhi {
                let qrow = &queries[q * d..(q + 1) * d];
                for j in j0..jhi {
                    out[q * n + j] =
                        sq_dist(qrow, &train[j * d..(j + 1) * d]);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// GEMM formulation
// ---------------------------------------------------------------------

/// The Gemm-formulation core over an **already-packed** training
/// operand: `pb` holds the `[d × n]` transposed training matrix packed
/// once into reuse-ordered [`PackedPanel`]s (built at fit time for the
/// instance learners, or once per fan-out on the calling thread — then
/// shared read-only by every worker and every query tile). The cross
/// term `q·t` runs through the register-blocked SIMD micro-kernel
/// directly into `out`, and one unit-stride pass rebuilds
/// `‖q‖² + ‖t‖² − 2·q·t`, clamped at 0 (see the module docs on
/// cancellation). Row norms come from the caller — a [`NormCache`] for
/// anything dataset-backed — so they are never recomputed here.
pub fn pairwise_sq_dists_gemm_packed(
    pb: &PackedPanel,
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(pb.k(), d, "pack depth must be the feature dimension");
    let n = pb.n();
    assert_eq!(queries.len() % d, 0);
    let nq = queries.len() / d;
    assert_eq!(train_norms.len(), n);
    assert_eq!(query_norms.len(), nq);
    assert_eq!(out.len(), nq * n);
    if n == 0 || nq == 0 {
        return;
    }
    out.fill(0.0);
    matmul_acc_prepacked(queries, pb, out, nq, t);
    for (q, orow) in out.chunks_exact_mut(n).enumerate() {
        let qn = query_norms[q];
        for (o, &tn) in orow.iter_mut().zip(train_norms) {
            *o = (qn + tn - 2.0 * *o).max(0.0);
        }
    }
}

/// The Gemm-formulation core over a **pre-transposed** training matrix:
/// packs `train_t` (`[d × n]`) into [`PackedPanel`]s and runs
/// [`pairwise_sq_dists_gemm_packed`]. Callers that hold the pack
/// itself (fused scans, the parallel fan-out) should call the packed
/// entry directly so the packing cost is paid once, not per call.
#[allow(clippy::too_many_arguments)]
pub fn pairwise_sq_dists_gemm_pre(
    train_t: &[f32],
    n: usize,
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train_t.len(), d * n);
    let pb = PackedPanel::pack(train_t, d, n, t.kc);
    pairwise_sq_dists_gemm_packed(&pb, queries, d, train_norms,
                                  query_norms, out, t);
}

/// GEMM-formulation pairwise distances over row-major operands:
/// transposes `train` once, then runs [`pairwise_sq_dists_gemm_pre`].
/// ≤ 1e-4 of the Exact kernels on well-scaled finite data
/// (property-tested), every distance clamped ≥ 0.
pub fn pairwise_sq_dists_gemm(
    train: &[f32],
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    assert_eq!(train.len() % d, 0);
    let n = train.len() / d;
    let train_t = transpose_rows(train, d);
    pairwise_sq_dists_gemm_pre(&train_t, n, queries, d, train_norms,
                               query_norms, out, t);
}

/// Formulation-dispatching sequential kernel: resolves `Auto` on this
/// call's multiply-adds, then runs the tiled Exact kernel or the Gemm
/// formulation. The norm slices are only read on the Gemm path (pass
/// empty slices when the policy is known to resolve Exact).
#[allow(clippy::too_many_arguments)]
pub fn pairwise_sq_dists_algo(
    algo: DistanceAlgo,
    train: &[f32],
    queries: &[f32],
    d: usize,
    train_norms: &[f32],
    query_norms: &[f32],
    out: &mut [f32],
    t: &TileConfig,
) {
    assert!(d > 0, "feature dimension must be positive");
    let n = train.len() / d;
    let nq = queries.len() / d;
    match algo.resolve(nq * n * d) {
        DistanceAlgo::Gemm => pairwise_sq_dists_gemm(
            train, queries, d, train_norms, query_norms, out, t),
        _ => pairwise_sq_dists_tiled(train, queries, d, out, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};

    #[test]
    fn hand_case() {
        let train = [0.0, 0.0, 3.0, 4.0]; // two 2-d points
        let queries = [0.0, 0.0];
        let mut out = [0.0f32; 2];
        pairwise_sq_dists_tiled(&train, &queries, 2, &mut out,
                                &TileConfig::westmere());
        assert_eq!(out, [0.0, 25.0]);
    }

    #[test]
    fn gemm_hand_case() {
        let train = [0.0, 0.0, 3.0, 4.0];
        let queries = [0.0, 0.0];
        let tn = row_sq_norms(&train, 2);
        let qn = row_sq_norms(&queries, 2);
        assert_eq!(tn, vec![0.0, 25.0]);
        let mut out = [-1.0f32; 2];
        pairwise_sq_dists_gemm(&train, &queries, 2, &tn, &qn, &mut out,
                               &TileConfig::westmere());
        assert_eq!(out, [0.0, 25.0]);
    }

    #[test]
    fn gather_rows_selects_rows_in_index_order() {
        let src = [0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0];
        assert_eq!(gather_rows(&src, 2, &[2, 0, 2]),
                   vec![20.0, 21.0, 0.0, 1.0, 20.0, 21.0]);
        assert!(gather_rows(&src, 2, &[]).is_empty());
    }

    #[test]
    fn tiled_is_bit_identical_to_naive() {
        check("pairwise-tiled-vs-naive", 30, |g| {
            let d = g.usize_in(1, 24);
            let n = g.usize_in(0, 50);
            let nq = g.usize_in(0, 20);
            let train = g.f32_vec(n * d, 3.0);
            let queries = g.f32_vec(nq * d, 3.0);
            // tiny tiles to force ragged edges
            let t = TileConfig {
                mc: 1,
                kc: 1,
                nc: 1,
                l1_f32: g.usize_in(2, 64) * d,
            };
            let mut want = vec![0.0f32; nq * n];
            let mut got = vec![-1.0f32; nq * n];
            pairwise_sq_dists_naive(&train, &queries, d, &mut want);
            pairwise_sq_dists_tiled(&train, &queries, d, &mut got, &t);
            prop_assert!(want == got, "tiled distances diverged");
            Ok(())
        });
    }

    #[test]
    fn transpose_rows_round_trips() {
        check("transpose-rows", 30, |g| {
            let d = g.usize_in(1, 12);
            let n = g.usize_in(0, 30);
            let rows = g.f32_vec(n * d, 2.0);
            let t = transpose_rows(&rows, d);
            prop_assert!(t.len() == rows.len(), "length changed");
            for i in 0..n {
                for f in 0..d {
                    prop_assert!(
                        t[f * n + i].to_bits() == rows[i * d + f].to_bits(),
                        "transpose moved ({i},{f}) wrong");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn row_sq_norms_match_sq_dist_from_origin() {
        check("row-norms", 25, |g| {
            let d = g.usize_in(1, 16);
            let n = g.usize_in(0, 30);
            let rows = g.f32_vec(n * d, 3.0);
            let norms = row_sq_norms(&rows, d);
            let zeros = vec![0.0f32; d];
            prop_assert!(norms.len() == n, "wrong norm count");
            for i in 0..n {
                let want = sq_dist(&rows[i * d..(i + 1) * d], &zeros);
                prop_assert!(want.to_bits() == norms[i].to_bits(),
                    "norm[{i}] diverged from sq_dist vs origin");
            }
            Ok(())
        });
    }

    #[test]
    fn norm_cache_counts_builds_and_gathers_without_recomputing() {
        let rows = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let before = norm_cache_builds();
        let cache = NormCache::compute(&rows, 2);
        assert_eq!(norm_cache_builds() - before, 1,
            "compute must count exactly one build on this thread");
        assert_eq!(cache.norms(), &[5.0, 25.0, 61.0]);
        assert_eq!(cache.len(), 3);
        assert!(!cache.is_empty());
        // gathers never touch the build counter
        assert_eq!(cache.gather(&[2, 0, 2]), vec![61.0, 5.0, 61.0]);
        assert_eq!(norm_cache_builds() - before, 1);
    }

    fn rand_tiles(g: &mut Gen) -> TileConfig {
        TileConfig {
            mc: g.usize_in(1, 9),
            kc: g.usize_in(1, 9),
            nc: g.usize_in(1, 9),
            l1_f32: 1 << g.usize_in(6, 10),
        }
    }

    #[test]
    fn gemm_matches_exact_within_tolerance_and_clamps() {
        // The acceptance parity contract on well-scaled data: every
        // Gemm distance within 1e-4 (relative) of the Exact oracle and
        // clamped at 0, across ragged shapes and ragged tiles.
        check("gemm-vs-exact", 30, |g| {
            let d = g.usize_in(1, 16);
            let n = g.usize_in(0, 40);
            let nq = g.usize_in(0, 20);
            let train = g.f32_vec(n * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let t = rand_tiles(g);
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let mut exact = vec![0.0f32; nq * n];
            let mut gemm = vec![-1.0f32; nq * n];
            pairwise_sq_dists_naive(&train, &queries, d, &mut exact);
            pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn,
                                   &mut gemm, &t);
            for i in 0..exact.len() {
                prop_assert!(gemm[i] >= 0.0,
                    "gemm[{i}] = {} escaped the clamp", gemm[i]);
                let tol = 1e-4 * exact[i].abs().max(1.0);
                prop_assert!((gemm[i] - exact[i]).abs() <= tol,
                    "gemm[{i}] {} vs exact {}", gemm[i], exact[i]);
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_pre_reuses_one_transpose_bit_for_bit() {
        // The pre-packed entry (what the fused scans and the parallel
        // fan-out call) must match the one-shot wrapper exactly.
        check("gemm-pre-vs-wrapper", 15, |g| {
            let d = g.usize_in(1, 10);
            let n = g.usize_in(1, 30);
            let nq = g.usize_in(1, 12);
            let train = g.f32_vec(n * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let t = rand_tiles(g);
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let mut want = vec![0.0f32; nq * n];
            pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn,
                                   &mut want, &t);
            let train_t = transpose_rows(&train, d);
            let mut got = vec![-1.0f32; nq * n];
            pairwise_sq_dists_gemm_pre(&train_t, n, &queries, d, &tn,
                                       &qn, &mut got, &t);
            prop_assert!(want == got, "pre-transposed gemm diverged");
            Ok(())
        });
    }

    #[test]
    fn gemm_packed_reuses_one_pack_bit_for_bit() {
        // The PackedPanel entry (what the fused scans and the parallel
        // fan-out hold across calls) must match the pack-per-call
        // entry exactly, and — because packed-matmul bits are
        // independent of blocking — the distances must not depend on
        // the tile config at all.
        check("gemm-packed-vs-pre", 15, |g| {
            let d = g.usize_in(1, 10);
            let n = g.usize_in(1, 30);
            let nq = g.usize_in(1, 12);
            let train = g.f32_vec(n * d, 1.0);
            let queries = g.f32_vec(nq * d, 1.0);
            let t = rand_tiles(g);
            let t2 = rand_tiles(g);
            let tn = row_sq_norms(&train, d);
            let qn = row_sq_norms(&queries, d);
            let train_t = transpose_rows(&train, d);
            let mut want = vec![0.0f32; nq * n];
            pairwise_sq_dists_gemm_pre(&train_t, n, &queries, d, &tn,
                                       &qn, &mut want, &t);
            let pb = PackedPanel::pack(&train_t, d, n, t.kc);
            for _ in 0..2 {
                let mut got = vec![-1.0f32; nq * n];
                pairwise_sq_dists_gemm_packed(&pb, &queries, d, &tn,
                                              &qn, &mut got, &t);
                prop_assert!(want == got, "reused pack diverged");
            }
            let mut other = vec![0.0f32; nq * n];
            pairwise_sq_dists_gemm_pre(&train_t, n, &queries, d, &tn,
                                       &qn, &mut other, &t2);
            prop_assert!(want == other,
                "gemm distances must not depend on the tile config");
            Ok(())
        });
    }

    #[test]
    fn near_duplicate_large_magnitude_rows_clamp_to_zero_not_nan() {
        // Regression (satellite): ‖q‖²+‖t‖²−2·q·t cancels
        // catastrophically on near-duplicate large-magnitude rows; the
        // raw sum can come out a few ulps negative, which would NaN a
        // downstream sqrt / Gaussian bandwidth pass. The clamp plus a
        // scale-aware error bound must hold.
        let d = 8;
        let n = 6;
        let base: Vec<f32> = (0..d).map(|f| 1.0e3 + f as f32).collect();
        let mut train = Vec::with_capacity(n * d);
        for i in 0..n {
            for f in 0..d {
                // rows differ by parts in 10^6: worst-case cancellation
                train.push(base[f] + i as f32 * 1.0e-3);
            }
        }
        let queries = train.clone();
        let tn = row_sq_norms(&train, d);
        let qn = row_sq_norms(&queries, d);
        let mut exact = vec![0.0f32; n * n];
        let mut gemm = vec![0.0f32; n * n];
        pairwise_sq_dists_naive(&train, &queries, d, &mut exact);
        pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn, &mut gemm,
                               &TileConfig::westmere());
        for q in 0..n {
            for j in 0..n {
                let v = gemm[q * n + j];
                assert!(v.is_finite() && v >= 0.0,
                    "gemm[{q},{j}] = {v} must be finite and clamped");
                assert!(v.sqrt().is_finite(),
                    "sqrt(gemm[{q},{j}]) must not NaN");
                // cancellation error is proportional to the norm scale,
                // not to the (tiny) true distance
                let scale = (qn[q] + tn[j]).max(1.0);
                assert!((v - exact[q * n + j]).abs() <= 1e-4 * scale,
                    "gemm[{q},{j}] {v} vs exact {} at scale {scale}",
                    exact[q * n + j]);
            }
        }
    }

    #[test]
    fn constant_feature_rows_clamp_to_near_zero() {
        // Regression (satellite): identical constant-feature rows have
        // exact distance 0; the Gemm reassociation may leave a few ulps
        // of residue but never a negative (or NaN-producing) value.
        let d = 5;
        let n = 4;
        let train = vec![7.5f32; n * d];
        let queries = vec![7.5f32; 2 * d];
        let tn = row_sq_norms(&train, d);
        let qn = row_sq_norms(&queries, d);
        let mut gemm = vec![-1.0f32; 2 * n];
        pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn, &mut gemm,
                               &TileConfig::westmere());
        let scale = tn[0] + qn[0];
        for (i, &v) in gemm.iter().enumerate() {
            assert!(v >= 0.0 && v <= 1e-4 * scale,
                "constant-feature gemm[{i}] = {v} (scale {scale})");
            assert!(v.sqrt().is_finite());
        }
    }

    #[test]
    fn dist_algo_parse_name_resolve_and_default() {
        assert_eq!(DistanceAlgo::parse("exact"), Some(DistanceAlgo::Exact));
        assert_eq!(DistanceAlgo::parse(" GEMM "), Some(DistanceAlgo::Gemm));
        assert_eq!(DistanceAlgo::parse("Auto"), Some(DistanceAlgo::Auto));
        assert_eq!(DistanceAlgo::parse("blas"), None);
        for a in [DistanceAlgo::Exact, DistanceAlgo::Gemm,
                  DistanceAlgo::Auto] {
            assert_eq!(DistanceAlgo::parse(a.name()), Some(a),
                "name() must round-trip through parse()");
        }
        // Auto splits on the work threshold; explicit choices pass
        // through regardless of work.
        assert_eq!(DistanceAlgo::Auto.resolve(MIN_GEMM_WORK),
                   DistanceAlgo::Gemm);
        assert_eq!(DistanceAlgo::Auto.resolve(MIN_GEMM_WORK - 1),
                   DistanceAlgo::Exact);
        assert_eq!(DistanceAlgo::Exact.resolve(usize::MAX),
                   DistanceAlgo::Exact);
        assert_eq!(DistanceAlgo::Gemm.resolve(0), DistanceAlgo::Gemm);
        // Briefly setting the override is safe for concurrent tests:
        // Exact only narrows what Auto would pick, and every
        // bit-parity test pins its algorithm explicitly.
        set_dist_algo(Some(DistanceAlgo::Exact));
        assert_eq!(default_dist_algo(), DistanceAlgo::Exact);
        set_dist_algo(None);
        let ambient = default_dist_algo();
        assert!(matches!(ambient, DistanceAlgo::Exact
                                  | DistanceAlgo::Gemm
                                  | DistanceAlgo::Auto));
    }

    #[test]
    fn algo_dispatch_picks_the_requested_formulation() {
        let mut g = Gen::new(17);
        let (d, n, nq) = (6usize, 20, 8);
        let train = g.f32_vec(n * d, 1.0);
        let queries = g.f32_vec(nq * d, 1.0);
        let t = TileConfig::westmere();
        let tn = row_sq_norms(&train, d);
        let qn = row_sq_norms(&queries, d);
        let mut exact = vec![0.0f32; nq * n];
        pairwise_sq_dists_tiled(&train, &queries, d, &mut exact, &t);
        let mut gemm = vec![0.0f32; nq * n];
        pairwise_sq_dists_gemm(&train, &queries, d, &tn, &qn, &mut gemm,
                               &t);
        // explicit Exact ignores the norm slices entirely
        let mut got = vec![0.0f32; nq * n];
        pairwise_sq_dists_algo(DistanceAlgo::Exact, &train, &queries, d,
                               &[], &[], &mut got, &t);
        assert_eq!(got, exact);
        // explicit Gemm is the gemm kernel verbatim
        let mut got = vec![0.0f32; nq * n];
        pairwise_sq_dists_algo(DistanceAlgo::Gemm, &train, &queries, d,
                               &tn, &qn, &mut got, &t);
        assert_eq!(got, gemm);
        // Auto below the MAC threshold is the Exact kernel
        assert!(nq * n * d < MIN_GEMM_WORK);
        let mut got = vec![0.0f32; nq * n];
        pairwise_sq_dists_algo(DistanceAlgo::Auto, &train, &queries, d,
                               &[], &[], &mut got, &t);
        assert_eq!(got, exact);
    }
}
