//! Tile-size selection driven by the cache model.
//!
//! Instead of hardcoding block sizes, [`TileConfig`] derives them from
//! [`LevelConfig`] parameters — the same data the `memsim` hierarchy
//! simulates — so the native kernels block for the machine the paper
//! reasons about (§5.1), and re-deriving for a different hierarchy is one
//! constructor call.
//!
//! Sizing rule (classic register/L1/L2 blocking, applied at f32
//! granularity with half-capacity budgets to leave room for the streams
//! the model does not account for):
//!
//! * `kc × nc` — the L1-resident panel of the stationary operand; `kc`
//!   and `nc` are balanced at `⌊√(L1/2 elems)⌋` rounded down to a power
//!   of two.
//! * `mc × kc` — the L2-resident block of the streamed operand:
//!   `mc = (L2/2 elems) / kc`, clamped to `[8, 1024]`.
//! * `l1_f32`  — the raw half-L1 element budget, used by the non-matmul
//!   kernels (pairwise distances, fused coupled step) whose working sets
//!   depend on runtime dimensions.

use super::pack::{round_up, MR, NR};
use crate::memsim::cache::{westmere_levels, LevelConfig};

const F32_BYTES: usize = 4;

/// Cache-blocking parameters for the native f32 kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Rows of the streamed operand per L2-resident block.
    pub mc: usize,
    /// Shared (reduction) dimension per L1-resident panel.
    pub kc: usize,
    /// Columns per L1-resident panel.
    pub nc: usize,
    /// Half of L1 capacity, in f32 elements (working-set budget).
    pub l1_f32: usize,
}

fn floor_pow2(x: usize) -> usize {
    if x <= 1 {
        1
    } else {
        1 << (usize::BITS - 1 - x.leading_zeros())
    }
}

impl TileConfig {
    /// Derive tile sizes from an ordered cache hierarchy (innermost
    /// first). Missing levels fall back to Westmere-like ratios.
    pub fn for_levels(levels: &[LevelConfig]) -> Self {
        let l1_bytes = levels
            .first()
            .map(|l| l.size_bytes as usize)
            .unwrap_or(32 << 10);
        let l2_bytes = levels
            .get(1)
            .map(|l| l.size_bytes as usize)
            .unwrap_or(8 * l1_bytes);
        let l1_f32 = (l1_bytes / 2 / F32_BYTES).max(64);
        let l2_f32 = (l2_bytes / 2 / F32_BYTES).max(l1_f32);
        let kc = floor_pow2((l1_f32 as f64).sqrt() as usize).max(8);
        let nc = floor_pow2(l1_f32 / kc).max(8);
        let mc = floor_pow2(l2_f32 / kc).clamp(8, 1024);
        Self { mc, kc, nc, l1_f32 }
    }

    /// Tiles for the paper's Westmere testbed — the default for every
    /// rewired learner path.
    pub fn westmere() -> Self {
        Self::for_levels(&westmere_levels())
    }

    /// Per-worker tiles for the parallel macro-tile layer. The L1/L2
    /// below the sharing point are private per core (Westmere §5.1), so
    /// the `kc × nc` panel and the L2-derived `mc` start from
    /// [`TileConfig::for_levels`] unchanged; the third level is shared
    /// by every worker, so each worker's streamed `mc × kc` block is
    /// additionally capped to its `1/workers` share of the half-L3
    /// budget — `workers` concurrent blocks must fit the shared level
    /// together instead of thrashing each other's working sets.
    ///
    /// `for_workers(levels, 1)` equals `for_levels(levels)` exactly:
    /// the single-thread path keeps PR-1 tile sizes bit-for-bit.
    pub fn for_workers(levels: &[LevelConfig], workers: usize) -> Self {
        let mut t = Self::for_levels(levels);
        let workers = workers.max(1);
        if workers > 1 {
            if let Some(l3) = levels.get(2) {
                let l3_f32 =
                    (l3.size_bytes as usize / 2 / F32_BYTES).max(64);
                let share = (l3_f32 / workers).max(64);
                let cap =
                    floor_pow2(share / t.kc.max(1)).clamp(8, 1024);
                t.mc = t.mc.min(cap);
            }
        }
        t
    }

    /// Per-worker tiles on the paper's testbed hierarchy — what the
    /// rewired learner paths use once a thread count is known.
    pub fn westmere_workers(workers: usize) -> Self {
        Self::for_workers(&westmere_levels(), workers)
    }

    /// Row-tile sizes `(queries, train rows)` for the pairwise-distance
    /// kernel: both tiles of `d`-wide rows must fit the L1 budget
    /// together so the train tile is reused across the whole query tile.
    pub fn pair_tiles(&self, d: usize) -> (usize, usize) {
        let rows = (self.l1_f32 / (2 * d.max(1))).clamp(1, 512);
        (rows, rows)
    }

    /// Batch-row tile for the fused coupled LR+SVM step: an `rb × kc`
    /// tile of the design matrix plus the four `kc`-wide weight/gradient
    /// panels must fit the L1 budget.
    pub fn coupled_rows(&self) -> usize {
        (self.l1_f32.saturating_sub(4 * self.kc) / self.kc.max(1))
            .clamp(1, 512)
    }

    /// Packing-buffer working set (in f32 elements) the packed matmul
    /// path holds live at any instant for an `m×k · k×n` product under
    /// these tiles: one `mc × kc` A macro-panel with rows rounded up to
    /// the `MR` register block, plus one `kc × nc` B panel with columns
    /// rounded up to `NR` (edge panels are zero-padded so the
    /// micro-kernel never branches on shape). This is what the memsim
    /// tile model charges the packed path on top of the operands
    /// themselves — the panels are *reused* across the whole macro-tile,
    /// so they are a footprint, not a traffic term.
    pub fn packed_footprint_f32(&self, m: usize, k: usize, n: usize)
        -> usize
    {
        let kb = self.kc.min(k);
        let a_panel = round_up(self.mc.min(m), MR) * kb;
        let b_panel = kb * round_up(self.nc.min(n), NR);
        a_panel + b_panel
    }

    /// F32 footprint of a fully prepacked B operand (`k × n`), i.e.
    /// what [`super::PackedPanel::pack`] allocates: every column panel
    /// rounded up to `NR`, all depth blocks resident at once. This is
    /// the pack-once-reuse cost the MLP pays per layer to keep its
    /// weights panel-ordered across predict calls; it depends only on
    /// the operand shape, not on the cache-derived tiles.
    pub fn prepacked_b_f32(k: usize, n: usize) -> usize {
        k * round_up(n, NR)
    }
}

impl Default for TileConfig {
    fn default() -> Self {
        Self::westmere()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::cache::WESTMERE_CORES_PER_L3;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn westmere_tiles_fit_their_levels() {
        // L1d 32 KiB → 4096 f32 budget → balanced 64×64 panel;
        // L2 256 KiB → 32768 f32 budget → mc = 512.
        let t = TileConfig::westmere();
        assert_eq!((t.mc, t.kc, t.nc), (512, 64, 64));
        assert_eq!(t.l1_f32, 4096);
        assert!(t.kc * t.nc * F32_BYTES <= 32 << 10);
        assert!(t.mc * t.kc * F32_BYTES <= 256 << 10);
    }

    #[test]
    fn degenerate_hierarchies_still_yield_usable_tiles() {
        let t = TileConfig::for_levels(&[]);
        assert_eq!(t, TileConfig::westmere()); // fallback = Westmere L1/L2
        let tiny = LevelConfig {
            name: "t",
            size_bytes: 128,
            ways: 1,
            line_bytes: 64,
            latency_cycles: 1,
        };
        let t = TileConfig::for_levels(&[tiny]);
        assert!(t.mc >= 1 && t.kc >= 1 && t.nc >= 1 && t.l1_f32 >= 64);
    }

    #[test]
    fn worker_tiles_match_single_core_at_one_and_shrink_under_pressure() {
        // workers = 1 is the PR-1 config bit-for-bit.
        assert_eq!(TileConfig::westmere_workers(1), TileConfig::westmere());
        // The 12 MiB shared L3 is roomy: up to the testbed's six cores
        // per socket the Westmere tiles are unchanged.
        assert_eq!(TileConfig::westmere_workers(WESTMERE_CORES_PER_L3),
                   TileConfig::westmere());
        // A pathologically small shared level must shrink the per-worker
        // streamed block (but never below the floor).
        let mut levels = westmere_levels();
        levels[2].size_bytes = 256 << 10;
        let t1 = TileConfig::for_workers(&levels, 1);
        let t8 = TileConfig::for_workers(&levels, 8);
        assert!(t8.mc < t1.mc, "mc {} must shrink below {}", t8.mc, t1.mc);
        assert!(t8.mc >= 8);
        assert_eq!((t8.kc, t8.nc, t8.l1_f32), (t1.kc, t1.nc, t1.l1_f32),
            "private-level tiles must not depend on worker count");
    }

    #[test]
    fn worker_tiles_respect_the_shared_level_share() {
        check("tile-worker-share", 40, |g| {
            let l1 = 1usize << g.usize_in(9, 16);
            let l2 = l1 << g.usize_in(0, 4);
            let l3 = l2 << g.usize_in(0, 6);
            let mk = |name, size: usize| LevelConfig {
                name,
                size_bytes: size as u64,
                ways: 8,
                line_bytes: 64,
                latency_cycles: 4,
            };
            let levels = [mk("L1", l1), mk("L2", l2), mk("L3", l3)];
            let w = g.usize_in(1, 16);
            let base = TileConfig::for_levels(&levels);
            let t = TileConfig::for_workers(&levels, w);
            prop_assert!(
                (t.kc, t.nc, t.l1_f32) == (base.kc, base.nc, base.l1_f32),
                "private-level tiles changed with workers");
            prop_assert!(t.mc <= base.mc, "mc grew: {} > {}", t.mc,
                base.mc);
            let l3_f32 = (l3 / 2 / F32_BYTES).max(64);
            prop_assert!(t.mc == 8 || w * t.mc * t.kc <= l3_f32,
                "{w} workers x {}x{} blocks exceed half-L3 budget {}",
                t.mc, t.kc, l3_f32);
            Ok(())
        });
    }

    #[test]
    fn packed_footprint_fits_the_blocking_budgets_on_westmere() {
        // The panels the packed path keeps live must fit the same
        // levels the tiles were derived for: the B panel (kc × nc
        // rounded to NR) inside the half-L1 budget, the A macro-panel
        // (mc × kc rounded to MR) inside the half-L2 budget. Westmere
        // tiles are already MR/NR-aligned, so rounding adds nothing.
        let t = TileConfig::westmere();
        let big = 1 << 20; // operands larger than any tile
        let a_panel = round_up(t.mc, MR) * t.kc;
        let b_panel = t.kc * round_up(t.nc, NR);
        assert_eq!(t.packed_footprint_f32(big, big, big),
                   a_panel + b_panel);
        assert!(b_panel <= t.l1_f32,
            "B panel {b_panel} exceeds half-L1 budget {}", t.l1_f32);
        assert!(a_panel * F32_BYTES <= 256 << 10,
            "A macro-panel {a_panel} exceeds the half-L2 budget");
    }

    #[test]
    fn packed_footprint_shrinks_with_the_operands() {
        check("tile-packed-footprint", 50, |g| {
            let t = TileConfig::westmere_workers(g.usize_in(1, 8));
            let (m, k, n) =
                (g.usize_in(1, 2048), g.usize_in(1, 2048),
                 g.usize_in(1, 2048));
            let fp = t.packed_footprint_f32(m, k, n);
            // Never below the live data actually packed...
            prop_assert!(
                fp >= t.mc.min(m) * t.kc.min(k)
                    + t.kc.min(k) * t.nc.min(n),
                "footprint {fp} below the unpadded panel volume");
            // ...and zero-padding is bounded by one register block per
            // panel edge.
            let pad = (MR - 1) * t.kc.min(k) + t.kc.min(k) * (NR - 1);
            prop_assert!(
                fp <= t.mc.min(m) * t.kc.min(k)
                    + t.kc.min(k) * t.nc.min(n) + pad,
                "footprint {fp} exceeds volume + edge padding {pad}");
            // Small operands must not be charged for full tiles.
            prop_assert!(t.packed_footprint_f32(1, 1, 1)
                <= round_up(1, MR) + round_up(1, NR),
                "tiny product charged a full macro-tile");
            // The prepacked-B accounting matches what PackedPanel
            // actually allocates: every depth block holds round_up(n,
            // NR) columns, k rows in total across blocks.
            prop_assert!(
                TileConfig::prepacked_b_f32(k, n)
                    == k * round_up(n, NR),
                "prepacked footprint diverged from the pack layout");
            Ok(())
        });
    }

    #[test]
    fn tiles_respect_budgets_across_random_hierarchies() {
        check("tile-budgets", 50, |g| {
            let l1 = 1usize << g.usize_in(7, 20);
            let l2 = l1 << g.usize_in(0, 6);
            let mk = |name, size: usize| LevelConfig {
                name,
                size_bytes: size as u64,
                ways: 8,
                line_bytes: 64,
                latency_cycles: 4,
            };
            let t = TileConfig::for_levels(&[mk("L1", l1), mk("L2", l2)]);
            prop_assert!(t.kc >= 1 && t.nc >= 1 && t.mc >= 1,
                "zero tile: {t:?}");
            prop_assert!(t.kc * t.nc <= t.l1_f32.max(64 * 64),
                "panel {}x{} exceeds L1 budget {}", t.kc, t.nc, t.l1_f32);
            let d = g.usize_in(1, 4096);
            let (qt, jt) = t.pair_tiles(d);
            prop_assert!(qt >= 1 && jt >= 1, "empty pair tile");
            prop_assert!(t.coupled_rows() >= 1, "empty coupled tile");
            Ok(())
        });
    }
}
