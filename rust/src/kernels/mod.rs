//! **L1-native kernels** (DESIGN.md layer L1, rust side): cache-blocked
//! f32 compute paths that apply the paper's locality guidelines to the
//! crate's own hot loops, mirroring the Pallas kernel layer
//! (`python/compile/kernels/`) point for point.
//!
//! | kernel | mirrors | paper hook |
//! |---|---|---|
//! | [`matmul_tiled`] (+ bias / transpose-acc variants) | `kernels/matmul.py` | Fig 3 / Alg 14–15 loop nests |
//! | [`pairwise_sq_dists_tiled`] | `kernels/distance.py` | Alg 10/11 distance pass |
//! | [`pairwise_sq_dists_gemm`] (+ [`NormCache`]) | `kernels/distance.py` | §4 "reuse of computation results": ‖q−t‖² = ‖q‖²+‖t‖²−2·q·t, cross term through the Fig 3 GEMM |
//! | [`coupled_step_tiled`] | `linear_coupled` graph | §4.3 coupled LR+SVM |
//! | [`matmul_packed`] (+ [`PackedPanel`] / [`MicroKernel`]) | — | register-level reuse: the hierarchy ladder's last rung — operands packed once into reuse-ordered panels, an `MR × NR` register block reused across the whole `K` reduction (Fig 3 taken down to the register file) |
//! | [`ServePolicy`] (+ `coordinator::serve`) | — | §4 "reuse of computation results" lifted to serving: live queries coalesced into micro-batches so one pass over the resident train tiles (norms + packed panels held across requests) is amortized over the whole batch instead of re-streamed per query |
//!
//! # Tiling scheme
//!
//! Every kernel blocks its loops so the operand that is *reused* stays
//! resident in a cache level while the operand that is *streamed* passes
//! through once:
//!
//! * **matmul** — `i-k-j` order inside `MC × KC × NC` blocks. The inner
//!   loop walks a row of `B` and a row of `C` with unit stride; a
//!   `KC × NC` panel of `B` is L1-resident across an `MC`-row block of
//!   `A` (L2-resident). Ragged edges are handled by clamping every tile
//!   to the matrix bounds, so no shape restrictions apply.
//! * **pairwise distances** — train-row × query-row tiles sized so both
//!   fit the L1 budget together; each train row fetched from memory is
//!   reused against the whole query tile instead of once per query.
//! * **coupled LR+SVM** — the §4.3 row-level coupling lifted to tiles:
//!   an `rb × kc` tile of the design matrix feeds the inner-product and
//!   gradient phases of *both* models while cache-hot.
//!
//! Tile sizes are not hardcoded: [`TileConfig::for_levels`] derives them
//! from the same [`crate::memsim::cache::LevelConfig`] parameters the
//! memory-hierarchy simulator runs on ([`TileConfig::westmere`] is the
//! paper's §5 testbed). The simulator predicts the miss-rate effects;
//! these kernels realise them on the host running the experiments.
//!
//! Below the cache tiles, the [`pack`] module adds the **register**
//! rung: A/B operands are packed once per macro-tile into contiguous
//! 32-byte-aligned panels ([`PackedPanel`]) ordered exactly as the
//! `MR × NR` register-blocked micro-kernel streams them, and one
//! [`MicroKernel`] dispatch point picks scalar / SSE2 / AVX2 at runtime
//! (`LOCALITY_ML_FORCE_SCALAR` pins the fallback). All tiers are
//! bit-identical, and the packed matmul is bit-identical to the naive
//! oracle — see `pack`'s module docs for why.
//!
//! The [`parallel`] layer shards these macro-tiles across a scoped
//! worker pool — `MC`-row blocks for matmul, query tiles for distances,
//! row blocks for the coupled step — with per-worker tile sizes from
//! [`TileConfig::for_workers`] (private L1/L2, a 1/workers share of the
//! shared L3). `threads = 1` short-circuits to the sequential kernels
//! above, bit for bit. A [`Schedule`] selects static contiguous
//! partitioning or dynamic work stealing per call; both produce the
//! same bits (partials merge by tile index, never completion order), so
//! the policy only moves wall-clock on skewed shapes.
//!
//! All three execution axes — worker count, schedule, distance
//! formulation — are carried by one [`ExecPolicy`] value
//! ([`policy`]): `ExecPolicy::default()` is fully-Auto,
//! [`ExecPolicy::resolve`] is the single CLI→env→Auto resolution
//! point, and every kernel/coordinator entry point takes
//! `&ExecPolicy` (the old bare `(threads, schedule[, algo])` tuple
//! signatures are gone).
//!
//! The **distance engine** additionally offers a second formulation
//! ([`DistanceAlgo`]): `Exact` keeps the bit-stable
//! subtract–square–accumulate pass, `Gemm` decomposes
//! `‖q−t‖² = ‖q‖² + ‖t‖² − 2·q·t` so the cross term runs through the
//! matmul micro-kernel over a [`NormCache`] of per-row squared norms
//! built once per dataset, and `Auto` picks by multiply-add count.
//! Resolution mirrors the threads/schedule policies: `--dist-algo` →
//! `LOCALITY_ML_DIST_ALGO` → `Auto`.
//!
//! # Correctness contract
//!
//! Every tiled kernel sums exactly the same multiset of terms as its
//! naive reference, and the naive paths stay in-tree as oracles. The
//! distance and coupled kernels also preserve accumulation *order*, so
//! they are bit-identical to their references; the matmul micro-kernel
//! reassociates within 4-deep groups for speed, so its parity contract
//! is ≤ 1e-4 — a contract the Gemm distance formulation inherits
//! (≤ 1e-4 vs Exact on well-scaled finite data, clamped ≥ 0; Exact
//! remains the oracle and the only formulation defined for non-finite
//! features). Property tests sweep random shapes — including sizes not
//! divisible by the tiles — and assert these bounds.

pub mod coupled;
pub mod distance;
pub mod matmul;
pub mod pack;
pub mod parallel;
pub mod policy;
pub mod tile;

pub use coupled::coupled_step_tiled;
pub use distance::{
    gather_rows, pairwise_sq_dists_algo, pairwise_sq_dists_gemm,
    pairwise_sq_dists_gemm_packed, pairwise_sq_dists_naive,
    pairwise_sq_dists_tiled, DistanceAlgo, NormCache,
};
pub use matmul::{
    matmul_acc_prepacked, matmul_acc_tiled, matmul_bias_prepacked,
    matmul_bias_tiled, matmul_naive, matmul_packed, matmul_tiled,
    matmul_tn_acc_naive, matmul_tn_acc_tiled,
};
pub use pack::{micro_kernel, MicroKernel, PackedPanel};
pub use policy::{
    default_chunk_rows, default_fault_spec, set_chunk_rows,
    set_fault_spec, set_retry_attempts, set_retry_backoff_us,
    ExecPolicy, RetryPolicy, ServePolicy,
};
pub use parallel::{
    coupled_step_exec, matmul_acc_exec, matmul_bias_exec,
    matmul_bias_prepacked_exec, matmul_exec, matmul_tn_acc_exec,
    pairwise_sq_dists_exec, pairwise_sq_dists_gather_exec,
    pairwise_sq_dists_gemm_exec, Schedule,
};
pub use tile::TileConfig;
