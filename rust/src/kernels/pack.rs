//! BLIS-style operand packing + the register-blocked SIMD micro-kernel.
//!
//! The cache-blocked kernels in [`super::matmul`] fix the *cache*-level
//! traffic but still stream unpacked row-major slices through a scalar
//! inner loop, so register/SIMD-level reuse — the last rung of the
//! paper's memory-hierarchy ladder — is left on the table. This module
//! supplies that rung:
//!
//! * **Packing** (`PackedPanel`, `pack_a_block`): operand panels are
//!   copied once per macro-tile into contiguous, 32-byte-aligned,
//!   reuse-ordered buffers. The B operand packs into `NR`-column panels
//!   (`p`-major within a panel: the micro-kernel streams it forward
//!   exactly once per C stripe); the A operand packs into `MR`-row
//!   panels (`p`-major, `MR` consecutive rows per slice — one broadcast
//!   each). Edge panels are zero-padded so the micro-kernel never
//!   branches on shape.
//! * **Micro-kernel** (`MicroKernel`): an `MR`×`NR` = 4×8 register
//!   block per C update — one AVX2 `ymm` (or two SSE2 `xmm`) of B per
//!   `p` step against four broadcast A scalars, accumulated in four
//!   (eight) vector registers. Tiers: `Scalar` (portable fallback,
//!   builds on any target), `Sse2` (x86-64 baseline), `Avx2` (runtime
//!   `is_x86_feature_detected!`). `LOCALITY_ML_FORCE_SCALAR` pins the
//!   fallback for CI parity legs.
//!
//! # Bit-stability contract
//!
//! Every tier gives each C element ONE accumulator, updated with a
//! separate multiply and add (never FMA) in ascending-`p` order, and
//! the accumulator is seeded from C itself, so:
//!
//! * `Scalar`, `Sse2` and `Avx2` produce **bit-identical** results
//!   (IEEE-754 lane-wise mul/add are exact per-lane operations — the
//!   vector width only changes how many independent chains advance per
//!   instruction, never a chain's order);
//! * per-element bits are independent of the `MR`/`NR`/`mc` blocking
//!   AND of `kc`: a C element's value is the chain
//!   `((c₀ + a·b) + a·b) + …` over `p = 0..k` regardless of how the
//!   loops are split, i.e. bit-identical to the naive `i–j–p` kernel.
//!
//! The zero padding preserves this: padded A×B lanes contribute
//! `0·0 = +0.0` to lanes that are masked off at write-back anyway, and
//! `x + 0.0 = x` for every finite/subnormal x the kernels see.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Micro-kernel register-block rows (A panel height).
pub const MR: usize = 4;
/// Micro-kernel register-block columns (B panel width) — one AVX2
/// vector, two SSE2 vectors.
pub const NR: usize = 8;

/// `x` rounded up to the next multiple of `m`.
pub fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

// ---------------------------------------------------------------------
// Aligned storage
// ---------------------------------------------------------------------

/// One 32-byte-aligned lane of 8 f32 — the allocation unit of packed
/// buffers, so `as_slice().as_ptr()` is always 32-byte aligned and the
/// AVX2 tier could use aligned loads (it uses `loadu`, which is
/// penalty-free on aligned addresses on every µarch this targets).
#[repr(C, align(32))]
#[derive(Clone, Copy)]
struct Lane([f32; 8]);

/// Contiguous, 32-byte-aligned, zero-initialised f32 buffer.
pub struct PackedBuf {
    lanes: Vec<Lane>,
    len: usize,
}

impl PackedBuf {
    /// A zeroed buffer holding `len` f32s (rounded up to whole lanes).
    pub fn zeroed(len: usize) -> Self {
        Self { lanes: vec![Lane([0.0; 8]); len.div_ceil(8)], len }
    }

    /// Number of f32s the buffer holds.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds no values.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The buffer as a `&[f32]` (32-byte-aligned base pointer).
    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: `lanes` is a contiguous Vec of repr(C) [f32; 8]
        // blocks, so the first `len` f32s are initialised, contiguous
        // and live as long as `self`.
        unsafe {
            std::slice::from_raw_parts(
                self.lanes.as_ptr().cast::<f32>(), self.len)
        }
    }

    /// The buffer as a `&mut [f32]` (32-byte-aligned base pointer).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: as `as_slice`, plus exclusive access via `&mut self`.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.lanes.as_mut_ptr().cast::<f32>(), self.len)
        }
    }
}

// ---------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------

/// Pack the `rows × kb` block of row-major `a` starting at
/// (`i0`, `p0`) into MR-row panels: panel `ip` holds rows
/// `i0 + ip*MR ..`, stored `p`-major as `kb` slices of `MR` values
/// (missing edge rows pad with zeros). `lda` is the row stride of `a`.
/// `dst` must hold `round_up(rows, MR) * kb` f32s.
pub fn pack_a_block(
    a: &[f32], lda: usize, i0: usize, rows: usize, p0: usize, kb: usize,
    dst: &mut [f32],
) {
    let panels = rows.div_ceil(MR);
    assert!(dst.len() >= panels * MR * kb);
    for ip in 0..panels {
        let base = ip * MR * kb;
        let live = MR.min(rows - ip * MR);
        for p in 0..kb {
            let s = base + p * MR;
            for i in 0..live {
                dst[s + i] = a[(i0 + ip * MR + i) * lda + (p0 + p)];
            }
            for i in live..MR {
                dst[s + i] = 0.0;
            }
        }
    }
}

/// A whole `k × n` row-major B operand packed for reuse: `kc`-deep
/// depth blocks, each split into `NR`-column panels stored `p`-major.
/// This is the once-per-operand layout the GEMM distance engine, the
/// fused scans and `NativeMlp` forward weights cache and re-stream —
/// pack once, multiply many times.
pub struct PackedPanel {
    buf: PackedBuf,
    /// logical depth (rows of B)
    k: usize,
    /// logical width (columns of B)
    n: usize,
    /// depth blocking the panels were packed with
    kc: usize,
    /// column-panel count = ceil(n / NR)
    np: usize,
    /// (p0, depth, buffer offset) per depth block
    blocks: Vec<(usize, usize, usize)>,
}

impl PackedPanel {
    /// Pack row-major `b` (`k × n`, row stride = `n`) with depth
    /// blocking `kc`.
    pub fn pack(b: &[f32], k: usize, n: usize, kc: usize) -> Self {
        assert_eq!(b.len(), k * n, "PackedPanel::pack: b is not k x n");
        let kc = kc.max(1);
        let np = n.div_ceil(NR).max(1);
        let mut blocks = Vec::with_capacity(k.div_ceil(kc).max(1));
        let mut total = 0usize;
        let mut p0 = 0usize;
        while p0 < k {
            let kb = kc.min(k - p0);
            blocks.push((p0, kb, total));
            total += np * NR * kb;
            p0 += kc;
        }
        if blocks.is_empty() {
            // k == 0: a single empty block keeps the driver loop trivial
            blocks.push((0, 0, 0));
        }
        let mut buf = PackedBuf::zeroed(total);
        {
            let dst = buf.as_mut_slice();
            for &(p0, kb, off) in &blocks {
                for jp in 0..np {
                    let j0 = jp * NR;
                    let live = NR.min(n.saturating_sub(j0));
                    let base = off + jp * NR * kb;
                    for p in 0..kb {
                        let s = base + p * NR;
                        let row = (p0 + p) * n + j0;
                        dst[s..s + live]
                            .copy_from_slice(&b[row..row + live]);
                        // padding lanes stay 0.0 from zeroed()
                    }
                }
            }
        }
        Self { buf, k, n, kc, np, blocks }
    }

    /// Depth (rows of the unpacked operand) this was packed from.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Width (columns of the unpacked operand) this was packed from.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Depth blocking this operand was packed with.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Number of NR-column panels.
    pub fn col_panels(&self) -> usize {
        self.np
    }

    /// The depth blocks as (p0, depth) pairs, ascending in `p0`.
    pub fn depth_blocks(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.blocks.iter().map(|&(p0, kb, _)| (p0, kb))
    }

    /// Packed data of column-panel `jp` within depth block `bi`:
    /// `depth * NR` f32s, `p`-major.
    pub fn panel(&self, bi: usize, jp: usize) -> &[f32] {
        let (_, kb, off) = self.blocks[bi];
        let s = off + jp * NR * kb;
        &self.buf.as_slice()[s..s + kb * NR]
    }

    /// Total packed footprint in f32s (padding included) — what the
    /// memsim tile model charges for a resident packed operand.
    pub fn footprint_f32(&self) -> usize {
        self.buf.len()
    }
}

// ---------------------------------------------------------------------
// Micro-kernel dispatch
// ---------------------------------------------------------------------

/// The register-blocked inner kernel tier. All tiers are bit-identical
/// (see module docs); the choice only moves wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroKernel {
    /// Portable scalar fallback — the only tier off x86-64.
    Scalar,
    /// x86-64 baseline: two 128-bit accumulator rows per C row.
    Sse2,
    /// Runtime-detected: one 256-bit accumulator row per C row.
    Avx2,
}

/// 0 = unset (read the env), 1 = force scalar, 2 = force auto.
static FORCE_SCALAR: AtomicU8 = AtomicU8::new(0);
static ENV_FORCE: OnceLock<bool> = OnceLock::new();
static DETECTED: OnceLock<MicroKernel> = OnceLock::new();

/// Does this `LOCALITY_ML_FORCE_SCALAR` value request the scalar tier?
/// Unset / empty / `0` / `false` / `off` (case-insensitive) mean no;
/// anything else pins the fallback.
pub fn parse_force_scalar(val: Option<&str>) -> bool {
    match val {
        None => false,
        Some(v) => {
            let v = v.trim();
            !(v.is_empty()
                || v.eq_ignore_ascii_case("0")
                || v.eq_ignore_ascii_case("false")
                || v.eq_ignore_ascii_case("off"))
        }
    }
}

/// Programmatic override of `LOCALITY_ML_FORCE_SCALAR` (tests/CLI);
/// `None` restores the environment default.
pub fn set_force_scalar(force: Option<bool>) {
    let v = match force {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    FORCE_SCALAR.store(v, Ordering::Relaxed);
}

fn force_scalar() -> bool {
    match FORCE_SCALAR.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => *ENV_FORCE.get_or_init(|| {
            parse_force_scalar(
                std::env::var("LOCALITY_ML_FORCE_SCALAR").ok().as_deref())
        }),
    }
}

impl MicroKernel {
    /// Is this tier runnable on the current CPU?
    pub fn available(self) -> bool {
        match self {
            MicroKernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            MicroKernel::Sse2 => true, // x86-64 baseline
            #[cfg(target_arch = "x86_64")]
            MicroKernel::Avx2 => std::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }

    /// Every tier runnable on the current CPU (always includes Scalar).
    pub fn supported() -> Vec<MicroKernel> {
        [MicroKernel::Scalar, MicroKernel::Sse2, MicroKernel::Avx2]
            .into_iter()
            .filter(|k| k.available())
            .collect()
    }

    /// Stable lower-case tier name (as printed by `--explain` and BENCH).
    pub fn name(self) -> &'static str {
        match self {
            MicroKernel::Scalar => "scalar",
            MicroKernel::Sse2 => "sse2",
            MicroKernel::Avx2 => "avx2",
        }
    }
}

fn detect_best() -> MicroKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return MicroKernel::Avx2;
        }
        MicroKernel::Sse2
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        MicroKernel::Scalar
    }
}

/// THE dispatch point: the tier every packed kernel runs unless handed
/// an explicit one. `LOCALITY_ML_FORCE_SCALAR` (or `set_force_scalar`)
/// pins `Scalar`; otherwise the best runtime-detected tier, cached.
pub fn micro_kernel() -> MicroKernel {
    if force_scalar() {
        return MicroKernel::Scalar;
    }
    *DETECTED.get_or_init(detect_best)
}

// ---------------------------------------------------------------------
// Micro-kernel implementations
// ---------------------------------------------------------------------

/// `acc[MR×NR] += Apanel · Bpanel` over `kb` depth steps, scalar tier.
/// `ap` is `p`-major `MR`-wide, `bp` is `p`-major `NR`-wide. The
/// per-element operation sequence (one mul, one add, ascending `p`) is
/// the contract every SIMD tier must reproduce bit-for-bit.
fn mk_scalar(ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR]) {
    for p in 0..kb {
        let arow = &ap[p * MR..p * MR + MR];
        let brow = &bp[p * NR..p * NR + NR];
        for i in 0..MR {
            let a = arow[i];
            let dst = &mut acc[i * NR..i * NR + NR];
            for j in 0..NR {
                dst[j] += a * brow[j];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// SSE2 tier: 8 xmm accumulators (two per C row). Separate
    /// `mul_ps` + `add_ps` — no FMA — so each lane's chain matches the
    /// scalar tier bit-for-bit.
    ///
    /// # Safety
    /// SSE2 is part of the x86-64 baseline; `ap`/`bp` must hold at
    /// least `kb*MR` / `kb*NR` elements (checked by the caller).
    pub unsafe fn mk_sse2(
        ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR],
    ) {
        debug_assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
        // SAFETY: the `# Safety` contract above — SSE2 is baseline on
        // x86-64, and every pointer offset stays under `kb*MR` for
        // `ap`, `kb*NR` for `bp`, `MR*NR` for `acc`, which the caller
        // (run_micro) asserts.
        unsafe {
            let mut c: [[__m128; 2]; MR] = [[_mm_setzero_ps(); 2]; MR];
            for (i, ci) in c.iter_mut().enumerate() {
                ci[0] = _mm_loadu_ps(acc.as_ptr().add(i * NR));
                ci[1] = _mm_loadu_ps(acc.as_ptr().add(i * NR + 4));
            }
            let a = ap.as_ptr();
            let b = bp.as_ptr();
            for p in 0..kb {
                let b0 = _mm_loadu_ps(b.add(p * NR));
                let b1 = _mm_loadu_ps(b.add(p * NR + 4));
                for (i, ci) in c.iter_mut().enumerate() {
                    let av = _mm_set1_ps(*a.add(p * MR + i));
                    ci[0] = _mm_add_ps(ci[0], _mm_mul_ps(av, b0));
                    ci[1] = _mm_add_ps(ci[1], _mm_mul_ps(av, b1));
                }
            }
            for (i, ci) in c.iter().enumerate() {
                _mm_storeu_ps(acc.as_mut_ptr().add(i * NR), ci[0]);
                _mm_storeu_ps(acc.as_mut_ptr().add(i * NR + 4), ci[1]);
            }
        }
    }

    /// AVX2 tier: 4 ymm accumulators, one per C row. Same
    /// mul-then-add chain as the scalar tier, 8 lanes at a time.
    ///
    /// # Safety
    /// Caller must have verified `avx2` via `is_x86_feature_detected!`;
    /// slice lengths as for [`mk_sse2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn mk_avx2(
        ap: &[f32], bp: &[f32], kb: usize, acc: &mut [f32; MR * NR],
    ) {
        debug_assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
        // SAFETY: the `# Safety` contract above — the caller verified
        // avx2, and every pointer offset stays under `kb*MR` for `ap`,
        // `kb*NR` for `bp`, `MR*NR` for `acc`, which the caller
        // (run_micro) asserts.
        unsafe {
            let mut c: [__m256; MR] = [_mm256_setzero_ps(); MR];
            for (i, ci) in c.iter_mut().enumerate() {
                *ci = _mm256_loadu_ps(acc.as_ptr().add(i * NR));
            }
            let a = ap.as_ptr();
            let b = bp.as_ptr();
            for p in 0..kb {
                let bv = _mm256_loadu_ps(b.add(p * NR));
                for (i, ci) in c.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*a.add(p * MR + i));
                    *ci = _mm256_add_ps(*ci, _mm256_mul_ps(av, bv));
                }
            }
            for (i, ci) in c.iter().enumerate() {
                _mm256_storeu_ps(acc.as_mut_ptr().add(i * NR), *ci);
            }
        }
    }
}

/// Run one micro-kernel invocation on the given tier.
/// Panics if the tier is not [`MicroKernel::available`] here.
pub fn run_micro(
    kernel: MicroKernel, ap: &[f32], bp: &[f32], kb: usize,
    acc: &mut [f32; MR * NR],
) {
    assert!(ap.len() >= kb * MR, "A panel shorter than kb*MR");
    assert!(bp.len() >= kb * NR, "B panel shorter than kb*NR");
    match kernel {
        MicroKernel::Scalar => mk_scalar(ap, bp, kb, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is unconditionally present on x86-64; bounds
        // asserted above.
        MicroKernel::Sse2 => unsafe { x86::mk_sse2(ap, bp, kb, acc) },
        #[cfg(target_arch = "x86_64")]
        MicroKernel::Avx2 => {
            assert!(kernel.available(),
                "AVX2 micro-kernel requested on a CPU without AVX2");
            // SAFETY: avx2 presence just asserted; bounds asserted
            // above.
            unsafe { x86::mk_avx2(ap, bp, kb, acc) }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => panic!("{} micro-kernel unavailable on this target",
                    kernel.name()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    #[test]
    fn pack_a_block_layout_and_padding() {
        // 3x4 block of a 5-wide matrix, rows 1..4, cols 1..5: one MR
        // panel, rows 3 live + 1 zero pad, p-major.
        let lda = 5;
        let a: Vec<f32> = (0..25).map(|v| v as f32).collect();
        let mut dst = vec![f32::NAN; round_up(3, MR) * 4];
        pack_a_block(&a, lda, 1, 3, 1, 4, &mut dst);
        for p in 0..4 {
            for i in 0..3 {
                assert_eq!(dst[p * MR + i], a[(1 + i) * lda + 1 + p],
                    "panel slice p={p} row {i}");
            }
            assert_eq!(dst[p * MR + 3], 0.0, "pad row at p={p}");
        }
    }

    #[test]
    fn packed_panel_layout_edges_and_footprint() {
        // k=5, n=11, kc=3: blocks (0,3) and (3,2); np=2 with 3 padded
        // columns in panel 1.
        let (k, n) = (5usize, 11usize);
        let b: Vec<f32> = (0..k * n).map(|v| v as f32 * 0.5).collect();
        let pb = PackedPanel::pack(&b, k, n, 3);
        assert_eq!(pb.col_panels(), 2);
        let blocks: Vec<_> = pb.depth_blocks().collect();
        assert_eq!(blocks, vec![(0, 3), (3, 2)]);
        assert_eq!(pb.footprint_f32(), 2 * NR * 3 + 2 * NR * 2);
        for (bi, &(p0, kb)) in blocks.iter().enumerate() {
            for jp in 0..pb.col_panels() {
                let panel = pb.panel(bi, jp);
                assert_eq!(panel.len(), kb * NR);
                for p in 0..kb {
                    for j in 0..NR {
                        let col = jp * NR + j;
                        let want = if col < n {
                            b[(p0 + p) * n + col]
                        } else {
                            0.0
                        };
                        assert_eq!(panel[p * NR + j], want,
                            "block {bi} panel {jp} p={p} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn packed_buf_is_32_byte_aligned() {
        for len in [1usize, 7, 8, 9, 1023] {
            let buf = PackedBuf::zeroed(len);
            assert_eq!(buf.as_slice().as_ptr() as usize % 32, 0);
            assert_eq!(buf.len(), len);
        }
    }

    #[test]
    fn all_supported_tiers_match_scalar_bitwise() {
        // The core SIMD contract: every runnable tier reproduces the
        // scalar chain exactly, including on non-zero seed accumulators
        // and ragged depths.
        let mut g = Gen::new(42);
        for _ in 0..40 {
            let kb = g.usize_in(1, 70);
            let ap = g.f32_vec(kb * MR, 2.0);
            let bp = g.f32_vec(kb * NR, 2.0);
            let seed = g.f32_vec(MR * NR, 1.0);
            let mut want = [0.0f32; MR * NR];
            want.copy_from_slice(&seed);
            mk_scalar(&ap, &bp, kb, &mut want);
            for tier in MicroKernel::supported() {
                let mut got = [0.0f32; MR * NR];
                got.copy_from_slice(&seed);
                run_micro(tier, &ap, &bp, kb, &mut got);
                assert_eq!(got, want,
                    "{} tier diverged from scalar at kb={kb}",
                    tier.name());
            }
        }
    }

    #[test]
    fn micro_kernel_chain_is_kc_split_invariant() {
        // Running one kb=K call must equal two chained calls at any
        // split point — the property that makes packed bits independent
        // of the kc blocking.
        let mut g = Gen::new(7);
        let k = 53usize;
        let ap = g.f32_vec(k * MR, 2.0);
        let bp = g.f32_vec(k * NR, 2.0);
        let mut whole = [0.0f32; MR * NR];
        mk_scalar(&ap, &bp, k, &mut whole);
        for split in [1usize, 8, 31, 52] {
            let mut parts = [0.0f32; MR * NR];
            mk_scalar(&ap[..split * MR], &bp[..split * NR], split,
                      &mut parts);
            mk_scalar(&ap[split * MR..], &bp[split * NR..], k - split,
                      &mut parts);
            assert_eq!(parts, whole, "split at {split} changed bits");
        }
    }

    #[test]
    fn force_scalar_parsing() {
        assert!(!parse_force_scalar(None));
        assert!(!parse_force_scalar(Some("")));
        assert!(!parse_force_scalar(Some("0")));
        assert!(!parse_force_scalar(Some("false")));
        assert!(!parse_force_scalar(Some("OFF")));
        assert!(parse_force_scalar(Some("1")));
        assert!(parse_force_scalar(Some("yes")));
        assert!(parse_force_scalar(Some("scalar")));
    }

    #[test]
    fn dispatch_returns_a_runnable_tier() {
        let k = micro_kernel();
        assert!(k.available(), "dispatched tier {k:?} not runnable");
        assert!(MicroKernel::Scalar.available());
        assert!(MicroKernel::supported().contains(&MicroKernel::Scalar));
    }

    #[test]
    fn zero_depth_panel_is_harmless() {
        let pb = PackedPanel::pack(&[], 0, 5, 64);
        assert_eq!(pb.k(), 0);
        assert_eq!(pb.n(), 5);
        assert_eq!(pb.depth_blocks().count(), 1);
        let (p0, kb) = pb.depth_blocks().next().unwrap();
        assert_eq!((p0, kb), (0, 0));
        assert_eq!(pb.panel(0, 0).len(), 0);
    }
}
