//! Tile-level fused coupled LR+SVM batch step (paper §4.3, extended).
//!
//! The paper couples logistic regression and the primal SVM at **row**
//! level: one traversal of each training row computes both inner
//! products, then both gradient contributions. This kernel extends the
//! coupling to **tile** level: the batch is processed in `rb × kc` tiles
//! of the design matrix (sized by [`TileConfig::coupled_rows`] /
//! `TileConfig::kc` so a tile plus the four `kc`-wide weight/gradient
//! panels fit the L1 budget), and each resident tile feeds *both* models
//! in both phases:
//!
//! 1. inner-product phase — the tile is swept feature-block by
//!    feature-block, accumulating the LR and SVM dot products for every
//!    row in the tile against the L1-resident weight panels;
//! 2. residual phase — per-row losses and gradient scalars for both
//!    models (pure row-local arithmetic, no matrix traffic);
//! 3. gradient phase — the *still cache-hot* tile is swept again,
//!    accumulating both gradients into the resident panels.
//!
//! The naive step reads each row once per phase from wherever it lives;
//! here the second sweep hits L1. All accumulation orders (dot products
//! over ascending features, gradients and losses over ascending rows)
//! match `learners::linear::coupled_step_naive` exactly, so the fused
//! step is bit-identical to the reference — asserted by the tests.

use super::tile::TileConfig;

/// Logistic sigmoid — the single shared implementation; the learner
/// reference (`learners::linear`) uses this same fn, so the kernel's
/// bit-identical contract cannot be voided by the two drifting apart.
pub(crate) fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Raw accumulation state of one coupled pass over a row block:
/// gradient sums and loss sums for BOTH models, before the batch
/// normalisation and weight update. The parallel layer computes one of
/// these per row block and reduces them in worker-index order.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct CoupledPartial {
    pub g_lr: Vec<f32>,
    pub g_svm: Vec<f32>,
    pub loss_lr: f32,
    pub loss_svm: f32,
}

/// One fused coupled minibatch step over row-major `x: [b×d]` with ±1
/// labels `y`. Returns `((w_lr', lr loss), (w_svm', svm loss))`, exactly
/// as `learners::linear::coupled_step` does.
pub fn coupled_step_tiled(
    w_lr: &[f32],
    w_svm: &[f32],
    x: &[f32],
    y: &[f32],
    lr: f32,
    lam: f32,
    t: &TileConfig,
) -> ((Vec<f32>, f32), (Vec<f32>, f32)) {
    let d = w_lr.len();
    assert_eq!(w_svm.len(), d);
    let b = y.len();
    assert_eq!(x.len(), b * d);
    let partial = coupled_accumulate(w_lr, w_svm, x, y, t);
    coupled_finalize(w_lr, w_svm, partial, b, lr, lam)
}

/// The tile-sweep phases 1–3 over one row block (`x`/`y` hold the
/// block's rows only), producing raw gradient and loss sums. Extracted
/// from the original fused step so `kernels::parallel` can fan row
/// blocks out to workers; the sequential step is `coupled_accumulate`
/// over the full batch followed by [`coupled_finalize`], arithmetic
/// unchanged.
pub(crate) fn coupled_accumulate(
    w_lr: &[f32],
    w_svm: &[f32],
    x: &[f32],
    y: &[f32],
    t: &TileConfig,
) -> CoupledPartial {
    let d = w_lr.len();
    assert_eq!(w_svm.len(), d);
    let b = y.len();
    assert_eq!(x.len(), b * d);
    let mut g_lr = vec![0.0f32; d];
    let mut g_svm = vec![0.0f32; d];
    let mut loss_lr = 0.0f32;
    let mut loss_svm = 0.0f32;
    let rb = t.coupled_rows();
    let kc = t.kc.max(1);
    let mut p_lr = vec![0.0f32; rb];
    let mut p_svm = vec![0.0f32; rb];
    let mut r_lr = vec![0.0f32; rb];
    let mut r_svm = vec![0.0f32; rb];
    for i0 in (0..b).step_by(rb) {
        let ihi = (i0 + rb).min(b);
        let rows = ihi - i0;
        // phase 1: both inner products, feature-block by feature-block
        p_lr[..rows].fill(0.0);
        p_svm[..rows].fill(0.0);
        for f0 in (0..d).step_by(kc) {
            let fhi = (f0 + kc).min(d);
            let wl = &w_lr[f0..fhi];
            let ws = &w_svm[f0..fhi];
            for i in i0..ihi {
                let row = &x[i * d + f0..i * d + fhi];
                let mut pl = p_lr[i - i0];
                let mut ps = p_svm[i - i0];
                for (f, &xv) in row.iter().enumerate() {
                    pl += xv * wl[f];
                    ps += xv * ws[f];
                }
                p_lr[i - i0] = pl;
                p_svm[i - i0] = ps;
            }
        }
        // phase 2: per-row residuals + losses (row order, both models)
        for i in i0..ihi {
            let m = -y[i] * p_lr[i - i0];
            loss_lr += m.max(0.0) + (-m.abs()).exp().ln_1p();
            r_lr[i - i0] = -y[i] * sigmoid(m);
            let margin = 1.0 - y[i] * p_svm[i - i0];
            r_svm[i - i0] = if margin > 0.0 {
                loss_svm += margin;
                -y[i]
            } else {
                0.0
            };
        }
        // phase 3: both gradients from the cache-hot tile
        for f0 in (0..d).step_by(kc) {
            let fhi = (f0 + kc).min(d);
            for i in i0..ihi {
                let rl = r_lr[i - i0];
                let rs = r_svm[i - i0];
                let row = &x[i * d + f0..i * d + fhi];
                let gl = &mut g_lr[f0..fhi];
                let gs = &mut g_svm[f0..fhi];
                for (f, &xv) in row.iter().enumerate() {
                    gl[f] += rl * xv;
                    gs[f] += rs * xv;
                }
            }
        }
    }
    CoupledPartial { g_lr, g_svm, loss_lr, loss_svm }
}

/// Batch normalisation + the coupled weight update, applied to reduced
/// accumulation state. `b` is the FULL batch size (the parallel layer
/// reduces partials over row blocks before calling this, so the
/// normalisation must not depend on block sizes).
pub(crate) fn coupled_finalize(
    w_lr: &[f32],
    w_svm: &[f32],
    p: CoupledPartial,
    b: usize,
    lr: f32,
    lam: f32,
) -> ((Vec<f32>, f32), (Vec<f32>, f32)) {
    let wsq: f32 = w_svm.iter().map(|v| v * v).sum();
    let loss_lr = p.loss_lr / b as f32;
    let loss_svm = p.loss_svm / b as f32 + 0.5 * lam * wsq;
    let scale = lr / b as f32;
    let w_lr2: Vec<f32> =
        w_lr.iter().zip(&p.g_lr).map(|(w, g)| w - scale * g).collect();
    let w_svm2: Vec<f32> = w_svm
        .iter()
        .zip(&p.g_svm)
        .map(|(w, g)| w - scale * g - lr * lam * w)
        .collect();
    ((w_lr2, loss_lr), (w_svm2, loss_svm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learners::linear;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn fused_step_is_bit_identical_to_the_naive_reference() {
        check("coupled-tiled-vs-naive", 40, |g| {
            let d = g.usize_in(1, 70);
            let b = g.usize_in(1, 70);
            let w0 = g.f32_vec(d, 1.0);
            let w1 = g.f32_vec(d, 1.0);
            let x = g.f32_vec(b * d, 2.0);
            let y: Vec<f32> = (0..b)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            // tiny ragged tiles AND the autotuned config
            let configs = [
                TileConfig { mc: 3, kc: g.usize_in(1, 9), nc: 3,
                             l1_f32: g.usize_in(8, 128) },
                TileConfig::westmere(),
            ];
            let want = linear::coupled_step_naive(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA);
            for t in configs {
                let got = coupled_step_tiled(
                    &w0, &w1, &x, &y, linear::LR, linear::LAMBDA, &t);
                prop_assert!(got == want,
                    "fused step diverged from reference with {t:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn parity_within_tolerance_on_larger_batches() {
        // The ISSUE-level contract: ≤ 1e-4 everywhere, ragged shapes
        // included (exact equality above is the stronger invariant).
        check("coupled-tolerance", 8, |g| {
            let d = g.usize_in(100, 200);
            let b = g.usize_in(100, 200);
            let w0 = g.f32_vec(d, 0.5);
            let w1 = g.f32_vec(d, 0.5);
            let x = g.f32_vec(b * d, 1.0);
            let y: Vec<f32> = (0..b)
                .map(|_| if g.bool() { 1.0 } else { -1.0 })
                .collect();
            let ((wl, ll), (ws, ls)) = linear::coupled_step_naive(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA);
            let ((wl2, ll2), (ws2, ls2)) = coupled_step_tiled(
                &w0, &w1, &x, &y, linear::LR, linear::LAMBDA,
                &TileConfig::westmere());
            for f in 0..d {
                prop_assert!((wl[f] - wl2[f]).abs() < 1e-4, "lr w[{f}]");
                prop_assert!((ws[f] - ws2[f]).abs() < 1e-4, "svm w[{f}]");
            }
            prop_assert!((ll - ll2).abs() < 1e-4, "lr loss");
            prop_assert!((ls - ls2).abs() < 1e-4, "svm loss");
            Ok(())
        });
    }
}
