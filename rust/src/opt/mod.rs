//! Optimizers over flat parameter vectors (DESIGN.md system S7).
//!
//! Fig 5 sweeps SGD, Momentum, Adam and Adagrad; all four are implemented
//! here on the rust side against the flat gradient the `mlp_grad_b*`
//! artifacts return.  Keeping the update in rust (a) needs one artifact
//! per batch size instead of per (optimizer × batch size) and (b) makes
//! the paper's §4.3 observation — "applying weight decay at each step may
//! be more expensive due to the complete traversal of the model" — a
//! directly measurable L3 cost.

/// The Fig 5 optimizer family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain stochastic gradient descent.
    Sgd,
    /// SGD with classical momentum (μ = 0.9).
    Momentum,
    /// Adam with the standard (β₁, β₂, ε) and bias correction.
    Adam,
    /// Adagrad with per-parameter accumulated squared gradients.
    Adagrad,
}

impl OptimizerKind {
    /// Every optimizer in the Fig 5 sweep, in report order.
    pub const ALL: [OptimizerKind; 4] = [
        OptimizerKind::Sgd,
        OptimizerKind::Momentum,
        OptimizerKind::Adam,
        OptimizerKind::Adagrad,
    ];

    /// Stable lower-case name (CLI/report token).
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Momentum => "momentum",
            OptimizerKind::Adam => "adam",
            OptimizerKind::Adagrad => "adagrad",
        }
    }

    /// Inverse of [`OptimizerKind::name`]; `None` for unknown tokens.
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Default learning rate per optimizer (the paper's "preliminary set
    /// of experiments ... to determine the best hyper-parameters" stands
    /// in for these choices; see EXPERIMENTS.md E1 for the sweep).
    pub fn default_lr(&self) -> f32 {
        match self {
            OptimizerKind::Sgd => 0.1,
            OptimizerKind::Momentum => 0.05,
            OptimizerKind::Adam => 1e-3,
            OptimizerKind::Adagrad => 1e-2,
        }
    }

    /// Build a fresh optimizer state for `params` parameters.
    pub fn build(&self, lr: f32, params: usize) -> Optimizer {
        let state = match self {
            OptimizerKind::Sgd => State::Sgd,
            OptimizerKind::Momentum => State::Momentum {
                mu: 0.9,
                v: vec![0.0; params],
            },
            OptimizerKind::Adam => State::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                m: vec![0.0; params],
                v: vec![0.0; params],
                t: 0,
            },
            OptimizerKind::Adagrad => State::Adagrad {
                eps: 1e-8,
                acc: vec![0.0; params],
            },
        };
        Optimizer { kind: *self, lr, state }
    }
}

enum State {
    Sgd,
    Momentum { mu: f32, v: Vec<f32> },
    Adam {
        beta1: f32,
        beta2: f32,
        eps: f32,
        m: Vec<f32>,
        v: Vec<f32>,
        t: u64,
    },
    Adagrad { eps: f32, acc: Vec<f32> },
}

/// A stateful optimizer over a flat parameter vector.
pub struct Optimizer {
    /// Which update rule this state implements.
    pub kind: OptimizerKind,
    /// Learning rate applied on every [`Optimizer::step`].
    pub lr: f32,
    state: State,
}

impl Optimizer {
    /// Apply one update in place: `params -= lr * f(grad)`.
    /// This is the paper's "complete traversal of the model" (§4.3) — a
    /// single fused pass over the flat vector, no allocation.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let lr = self.lr;
        match &mut self.state {
            State::Sgd => {
                for (p, &g) in params.iter_mut().zip(grad) {
                    *p -= lr * g;
                }
            }
            State::Momentum { mu, v } => {
                for ((p, &g), v) in params.iter_mut().zip(grad)
                    .zip(v.iter_mut()) {
                    *v = *mu * *v + g;
                    *p -= lr * *v;
                }
            }
            State::Adam { beta1, beta2, eps, m, v, t } => {
                *t += 1;
                let t = *t as i32;
                let bc1 = 1.0 - beta1.powi(t);
                let bc2 = 1.0 - beta2.powi(t);
                for (((p, &g), m), v) in params.iter_mut().zip(grad)
                    .zip(m.iter_mut()).zip(v.iter_mut()) {
                    *m = *beta1 * *m + (1.0 - *beta1) * g;
                    *v = *beta2 * *v + (1.0 - *beta2) * g * g;
                    let mh = *m / bc1;
                    let vh = *v / bc2;
                    *p -= lr * mh / (vh.sqrt() + *eps);
                }
            }
            State::Adagrad { eps, acc } => {
                for ((p, &g), a) in params.iter_mut().zip(grad)
                    .zip(acc.iter_mut()) {
                    *a += g * g;
                    *p -= lr * g / (a.sqrt() + *eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn sgd_closed_form() {
        let mut o = OptimizerKind::Sgd.build(0.5, 2);
        let mut p = vec![1.0, -2.0];
        o.step(&mut p, &[2.0, 2.0]);
        assert_eq!(p, vec![0.0, -3.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut o = OptimizerKind::Momentum.build(1.0, 1);
        let mut p = vec![0.0];
        o.step(&mut p, &[1.0]); // v = 1,      p = -1
        o.step(&mut p, &[1.0]); // v = 1.9,    p = -2.9
        assert!((p[0] + 2.9).abs() < 1e-6, "p={}", p[0]);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // With bias correction, |Δp| of step 1 ≈ lr regardless of gradient
        // scale (the classic Adam sanity check).
        for &scale in &[1e-3f32, 1.0, 1e3] {
            let mut o = OptimizerKind::Adam.build(0.01, 1);
            let mut p = vec![0.0];
            o.step(&mut p, &[scale]);
            assert!((p[0].abs() - 0.01).abs() < 1e-4,
                "step size {} for grad scale {scale}", p[0].abs());
        }
    }

    #[test]
    fn adagrad_decays_effective_rate() {
        let mut o = OptimizerKind::Adagrad.build(1.0, 1);
        let mut p = vec![0.0];
        o.step(&mut p, &[1.0]);
        let first = p[0].abs();
        let before = p[0];
        o.step(&mut p, &[1.0]);
        let second = (p[0] - before).abs();
        assert!(second < first, "rate must decay: {second} !< {first}");
    }

    #[test]
    fn all_optimizers_descend_a_quadratic() {
        // f(p) = 0.5 * |p|^2, grad = p: every optimizer must reduce |p|.
        check("optimizers-descend", 20, |g| {
            for kind in OptimizerKind::ALL {
                let n = g.usize_in(1, 32);
                let mut p = g.f32_vec(n, 5.0);
                let p0: f32 = p.iter().map(|x| x * x).sum();
                let mut o = kind.build(kind.default_lr(), n);
                for _ in 0..50 {
                    let grad = p.clone();
                    o.step(&mut p, &grad);
                }
                let p1: f32 = p.iter().map(|x| x * x).sum();
                prop_assert!(p1 < p0 || p0 == 0.0,
                    "{:?} did not descend: {p0} -> {p1}", kind);
            }
            Ok(())
        });
    }

    #[test]
    fn kind_name_roundtrip() {
        for kind in OptimizerKind::ALL {
            assert_eq!(OptimizerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(OptimizerKind::parse("rmsprop"), None);
    }

    #[test]
    #[should_panic]
    fn mismatched_grad_length_panics() {
        let mut o = OptimizerKind::Sgd.build(0.1, 2);
        let mut p = vec![0.0, 0.0];
        o.step(&mut p, &[1.0]);
    }
}
