//! # locality-ml
//!
//! A locality-aware machine-learning runtime reproducing *"Guidelines for
//! enhancing data locality in selected machine learning algorithms"*
//! (Chakroun, Vander Aa, Ashby — IDA 2020, DOI 10.3233/IDA-184287).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L3 (this crate)** — coordinator: fold streams, the SW-SGD sliding
//!   window, the joint k-NN+PRW executor, samplers, optimizers, metrics and
//!   the memory-hierarchy simulator that stands in for the paper's testbed.
//! * **L2 (python/compile)** — JAX compute graphs (MLP fwd/bwd, fused
//!   k-NN+PRW, coupled LR+SVM, naive Bayes), AOT-lowered to HLO text once.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (tiled matmul, tiled pairwise distances, fused window gradient).
//!
//! The compiled artifacts in `artifacts/` are executed from rust through
//! the PJRT C API ([`runtime`]); python never runs on the request path.
//!
//! The L1 layer also has a **native rust side**: [`kernels`] provides
//! cache-blocked matmul / pairwise-distance / fused-coupled-step paths
//! whose tile sizes are derived from the [`memsim`] cache model, so the
//! learners' hot loops apply the same locality guidelines the simulator
//! measures. Naive row-at-a-time references stay in-tree as oracles,
//! and `kernels::parallel` shards the macro-tiles across a scoped
//! worker pool (`--threads` / `LOCALITY_ML_THREADS`; one thread spawns
//! nothing and, for the row-disjoint kernels, is the exact sequential
//! path) with per-worker tiles sized from the shared L3, under a static
//! or work-stealing schedule (`--schedule` / `LOCALITY_ML_SCHEDULE`;
//! both produce identical bits).

// Every public item carries rustdoc; the contracts (bit-parity,
// determinism across threads/schedules/batching) live on the items
// that promise them, so `cargo doc` is the API reference.
#![warn(missing_docs)]
// Every unsafe operation inside an `unsafe fn` still needs its own
// `unsafe {}` block with a `// SAFETY:` comment — enforced without a
// toolchain by `scripts/lint/` (rule: undocumented-unsafe).
#![deny(unsafe_op_in_unsafe_fn)]
// Clippy policy: the loop nests deliberately mirror the paper's
// pseudo-code (explicit indices keep the access patterns auditable
// against Algorithms 1-15), and the kernel/learner APIs use flat
// argument lists rather than parameter structs.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::identity_op,
    clippy::erasing_op,
    clippy::manual_memcpy,
    clippy::new_without_default
)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod learners;
pub mod opt;
pub mod memsim;
pub mod metrics;
pub mod runtime;
pub mod util;
