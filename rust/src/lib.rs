//! # locality-ml
//!
//! A locality-aware machine-learning runtime reproducing *"Guidelines for
//! enhancing data locality in selected machine learning algorithms"*
//! (Chakroun, Vander Aa, Ashby — IDA 2020, DOI 10.3233/IDA-184287).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L3 (this crate)** — coordinator: fold streams, the SW-SGD sliding
//!   window, the joint k-NN+PRW executor, samplers, optimizers, metrics and
//!   the memory-hierarchy simulator that stands in for the paper's testbed.
//! * **L2 (python/compile)** — JAX compute graphs (MLP fwd/bwd, fused
//!   k-NN+PRW, coupled LR+SVM, naive Bayes), AOT-lowered to HLO text once.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (tiled matmul, tiled pairwise distances, fused window gradient).
//!
//! The compiled artifacts in `artifacts/` are executed from rust through
//! the PJRT C API ([`runtime`]); python never runs on the request path.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod learners;
pub mod opt;
pub mod memsim;
pub mod metrics;
pub mod runtime;
pub mod util;
