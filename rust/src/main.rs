//! `locality-ml` — launcher for the locality-aware ML runtime.
//!
//! Every subcommand regenerates one of the paper's experimental artifacts
//! (see DESIGN.md §3 for the experiment index):
//!
//! ```text
//! locality-ml train   [--config f.toml] [--epochs N] [--cv]
//!                     [--optimizers a,b] [--windows 0,1,2]
//!                     [--dataset-n N] [--out-csv path]    Fig 5  (E1)
//! locality-ml joint   [--config f.toml] [--data-dir d]    Table 1 (E2)
//! locality-ml fig4                                        Fig 4  (E3)
//! locality-ml interchange [--n N] [--m M]                 Alg 1/2 (E4)
//! locality-ml cache-model                                 §5.1   (E5)
//! locality-ml audit                                       §3-§4  (E6)
//! locality-ml kernels  [--sizes ...] [--out-json f]       E12
//! locality-ml parallel [--sizes ...] [--curve 1,2,4]      E13
//! locality-ml sweep    [--dataset-n N] [--ks 1,3,5]
//!                      [--bandwidth-mults 0.5,1,2,4]
//!                      [--curve 1,2,4] [--out-json f]     E14
//! locality-ml steal    [--dataset-n N] [--fold-weights 8,4,2,1]
//!                      [--curve 1,2,4] [--out-json f]     E15
//! locality-ml dists    [--train-n N] [--queries N] [--d D]
//!                      [--out-json f]                     E16
//! locality-ml pack     [--sizes ...] [--out-json f]       E17
//! locality-ml serve    [--train-n N] [--max-batch N]
//!                      [--max-wait-us N] [--queue-cap N]
//!                      [--socket path]                    E18
//! locality-ml serve-bench [--train-n N] [--queries N]
//!                      [--batches 1,8,64] [--out-json f]  E19
//! locality-ml convert [--in d.lmld] [--out d.lmtc]
//!                      [--train-n N]                      E20
//! locality-ml ooc     [--train-n N] [--queries N]
//!                      [--store d.lmtc]
//!                      [--chunk-sizes 256,512,2000]       E21
//! locality-ml info    [--artifacts dir]
//! ```
//!
//! Every subcommand accepts `--threads N` (parallel macro-tile layer;
//! 1 = the exact single-thread kernels), `--schedule
//! static|stealing|auto` (macro-tile scheduling policy — identical
//! output bits either way), `--dist-algo exact|gemm|auto` (distance
//! formulation: exact is the bit-stable oracle, gemm the cached-norm
//! GEMM decomposition within 1e-4 of it) and `--chunk-rows N` (feature
//! rows per chunk for newly written out-of-core `.lmtc` stores —
//! chunking never changes output bits, only the resident working set).
//!
//! The fault-tolerance knobs ride the same chain: `--fault-spec SPEC`
//! (deterministic fault injection into the chunked store reader — off
//! unless set), `--retry-attempts N` and `--retry-backoff-us N`
//! (bounded retry for transient store faults). An injected fault never
//! changes the bits of a successful result (determinism contract 7);
//! failures surface as typed errors, never panics.

use std::path::PathBuf;

use anyhow::Result;

use locality_ml::cli::{commands, Args};
use locality_ml::config::{Config, JointExperiment, TrainExperiment};
use locality_ml::opt::OptimizerKind;

fn load_config(args: &Args) -> Result<Config> {
    match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path)),
        None => Ok(Config::default()),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    // Global `--threads N` for the parallel macro-tile layer (default:
    // LOCALITY_ML_THREADS, then available parallelism; 1 = the exact
    // single-thread kernels).
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse()
            .map_err(|_| anyhow::anyhow!("--threads: bad integer `{t}`"))?;
        anyhow::ensure!(n >= 1, "--threads must be >= 1");
        locality_ml::kernels::parallel::set_threads(n);
    }
    // Global `--schedule static|stealing|auto` for the macro-tile
    // scheduling policy (default: LOCALITY_ML_SCHEDULE, then auto).
    // Both policies produce identical bits; this only moves wall-clock
    // on skewed shapes.
    if let Some(s) = args.get("schedule") {
        let sched = locality_ml::kernels::Schedule::parse(s)
            .ok_or_else(|| anyhow::anyhow!(
                "--schedule: `{s}` is not one of static|stealing|auto"))?;
        locality_ml::kernels::parallel::set_schedule(Some(sched));
    }
    // Global `--dist-algo exact|gemm|auto` for the distance engine
    // (default: LOCALITY_ML_DIST_ALGO, then auto). Exact is the
    // bit-stable oracle; gemm is the ‖q‖²+‖t‖²−2·q·t formulation over
    // cached row norms (≤ 1e-4 of exact, clamped ≥ 0); auto picks per
    // call by multiply-add count.
    if let Some(s) = args.get("dist-algo") {
        let algo = locality_ml::kernels::DistanceAlgo::parse(s)
            .ok_or_else(|| anyhow::anyhow!(
                "--dist-algo: `{s}` is not one of exact|gemm|auto"))?;
        locality_ml::kernels::distance::set_dist_algo(Some(algo));
    }
    // Global `--chunk-rows N` for newly written out-of-core `.lmtc`
    // stores (default: LOCALITY_ML_CHUNK_ROWS, then a ~4 MiB auto
    // size). Chunking never changes output bits — this only trades
    // resident working set against streaming overhead.
    if let Some(c) = args.get("chunk-rows") {
        let n: usize = c.parse().map_err(
            |_| anyhow::anyhow!("--chunk-rows: bad integer `{c}`"))?;
        anyhow::ensure!(n >= 1, "--chunk-rows must be >= 1");
        locality_ml::kernels::set_chunk_rows(Some(n));
    }
    // Global `--fault-spec SPEC` for deterministic fault injection into
    // the chunked `.lmtc` reader (default: LOCALITY_ML_FAULT_SPEC, then
    // off). Validated here so a typo fails the launch, not the first
    // scan. Injection never changes the bits of a successful result
    // (determinism contract 7) — it only turns reads into typed errors.
    if let Some(s) = args.get("fault-spec") {
        locality_ml::data::FaultSpec::parse(s).map_err(
            |e| anyhow::anyhow!("--fault-spec: {e}"))?;
        locality_ml::kernels::set_fault_spec(Some(s.to_string()));
    }
    // Global `--retry-attempts N` / `--retry-backoff-us N` for the
    // transient-fault retry loop in the chunked reader (defaults:
    // LOCALITY_ML_RETRY_ATTEMPTS / LOCALITY_ML_RETRY_BACKOFF_US, then
    // 3 attempts / 100 us).
    if let Some(a) = args.get("retry-attempts") {
        let n: u32 = a.parse().map_err(
            |_| anyhow::anyhow!("--retry-attempts: bad integer `{a}`"))?;
        anyhow::ensure!(n >= 1, "--retry-attempts must be >= 1");
        locality_ml::kernels::set_retry_attempts(Some(n));
    }
    if let Some(b) = args.get("retry-backoff-us") {
        let us: u64 = b.parse().map_err(
            |_| anyhow::anyhow!("--retry-backoff-us: bad integer `{b}`"))?;
        locality_ml::kernels::set_retry_backoff_us(Some(us));
    }
    match args.command.as_str() {
        "train" => {
            let cfg = load_config(&args)?;
            let mut exp = TrainExperiment::from_config(&cfg)?;
            // CLI overrides
            exp.epochs = args.usize_or("epochs", exp.epochs)?;
            exp.dataset_n = args.usize_or("dataset-n", exp.dataset_n)?;
            exp.seed = args.u64_or("seed", exp.seed)?;
            exp.cross_validate = args.flag("cv") || exp.cross_validate;
            if args.get("optimizers").is_some() {
                exp.optimizers = args
                    .list_or("optimizers", &[])
                    .iter()
                    .map(|s| OptimizerKind::parse(s).ok_or_else(
                        || anyhow::anyhow!("unknown optimizer `{s}`")))
                    .collect::<Result<_>>()?;
            }
            if args.get("windows").is_some() {
                exp.windows = args
                    .list_or("windows", &[])
                    .iter()
                    .map(|s| s.parse::<usize>().map_err(
                        |_| anyhow::anyhow!("bad window `{s}`")))
                    .collect::<Result<_>>()?;
            }
            if let Some(p) = args.get("out-csv") {
                exp.out_csv = Some(PathBuf::from(p));
            }
            if let Some(p) = args.get("artifacts") {
                exp.artifacts = PathBuf::from(p);
            }
            commands::cmd_train(&exp)?;
        }
        "joint" => {
            let cfg = load_config(&args)?;
            let mut exp = JointExperiment::from_config(&cfg)?;
            if let Some(p) = args.get("data-dir") {
                exp.data_dir = PathBuf::from(p);
            }
            if let Some(p) = args.get("artifacts") {
                exp.artifacts = PathBuf::from(p);
            }
            exp.seed = args.u64_or("seed", exp.seed)?;
            exp.regenerate = args.flag("regenerate") || exp.regenerate;
            commands::cmd_joint(&exp)?;
        }
        "fig4" => {
            commands::cmd_fig4()?;
        }
        "interchange" => {
            let n = args.u64_or("n", 256)?;
            let m = args.u64_or("m", 256)?;
            commands::cmd_interchange(n, m)?;
        }
        "cache-model" => {
            commands::cmd_cache_model()?;
        }
        "audit" => {
            commands::cmd_audit()?;
        }
        "kernels" => {
            let sizes = args.usize_list_or("sizes", &[256, 512])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_kernels(&sizes, out.as_deref())?;
        }
        "parallel" => {
            let sizes = args.usize_list_or("sizes", &[256, 512])?;
            let curve = args.usize_list_or("curve", &[1, 2, 4])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_parallel(&sizes, &curve, out.as_deref())?;
        }
        "sweep" => {
            let n = args.usize_or("dataset-n", 1000)?;
            let folds = args.usize_or("folds", 5)?;
            let seed = args.u64_or("seed", 7)?;
            let ks = args.usize_list_or("ks", &[1, 3, 5, 9, 15])?;
            let mults = args
                .f32_list_or("bandwidth-mults", &[0.5, 1.0, 2.0, 4.0])?;
            let curve = args.usize_list_or("curve", &[1, 2, 4])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_sweep(n, folds, &ks, &mults, &curve, seed,
                                out.as_deref())?;
        }
        "steal" => {
            let n = args.usize_or("dataset-n", 2000)?;
            let seed = args.u64_or("seed", 7)?;
            let ks = args.usize_list_or("ks", &[1, 3, 5, 9, 15])?;
            let mults = args
                .f32_list_or("bandwidth-mults", &[0.5, 1.0, 2.0, 4.0])?;
            // descending weights: the static contiguous partition
            // stacks the expensive splits onto worker 0 — the
            // skewed-shape scenario the scheduler exists for
            let weights = args.usize_list_or(
                "fold-weights", &[8, 7, 6, 5, 4, 3, 2, 1, 1, 1, 1, 1])?;
            let curve = args.usize_list_or("curve", &[1, 2, 4])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_steal(n, &weights, &ks, &mults, &curve, seed,
                                out.as_deref())?;
        }
        "dists" => {
            let n = args.usize_or("train-n", 4000)?;
            let nq = args.usize_or("queries", 1000)?;
            let d = args.usize_or("d", 64)?;
            let seed = args.u64_or("seed", 7)?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_dists(n, nq, d, seed, out.as_deref())?;
        }
        "pack" => {
            let sizes = args.usize_list_or("sizes", &[256, 512])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_pack(&sizes, out.as_deref())?;
        }
        "serve" => {
            let train_n = args.usize_or("train-n", 4000)?;
            let seed = args.u64_or("seed", 7)?;
            // 0 / u64::MAX are the "auto" sentinels: unset knobs fall
            // through to LOCALITY_ML_MAX_BATCH / _MAX_WAIT_US /
            // _QUEUE_CAP, then the compiled defaults (64 / 2000 / 1024)
            let policy = locality_ml::kernels::ServePolicy::auto()
                .with_max_batch(args.usize_or("max-batch", 0)?)
                .with_max_wait_us(
                    args.u64_or("max-wait-us", u64::MAX)?)
                .with_queue_cap(args.usize_or("queue-cap", 0)?);
            let socket = args.get("socket").map(PathBuf::from);
            commands::cmd_serve(train_n, seed, policy,
                                socket.as_deref())?;
        }
        "serve-bench" => {
            let train_n = args.usize_or("train-n", 4000)?;
            let nq = args.usize_or("queries", 512)?;
            let seed = args.u64_or("seed", 7)?;
            let batches = args.usize_list_or("batches", &[1, 8, 64])?;
            let out = args.get("out-json").map(PathBuf::from);
            commands::cmd_serve_bench(train_n, nq, seed, &batches,
                                      out.as_deref())?;
        }
        "convert" => {
            let input = args.get("in").map(PathBuf::from);
            let out = PathBuf::from(args.str_or("out", "data/train.lmtc"));
            let train_n = args.usize_or("train-n", 4000)?;
            let seed = args.u64_or("seed", 7)?;
            commands::cmd_convert(input.as_deref(), &out, train_n, seed)?;
        }
        "ooc" => {
            let store =
                PathBuf::from(args.str_or("store", "data/train.lmtc"));
            if args.flag("verify") {
                // deep integrity scan of an existing store: header +
                // metadata checks at open, then every chunk re-read
                // and CRC-verified (v2; v1 streams without checksums)
                commands::cmd_verify_store(&store)?;
            } else {
                let train_n = args.usize_or("train-n", 4000)?;
                let nq = args.usize_or("queries", 256)?;
                let seed = args.u64_or("seed", 7)?;
                // an empty list defers to the session chain (the
                // global --chunk-rows flag / LOCALITY_ML_CHUNK_ROWS /
                // auto)
                let sizes = args.usize_list_or("chunk-sizes", &[])?;
                let out = args.get("out-json").map(PathBuf::from);
                commands::cmd_ooc(train_n, nq, seed, &store, &sizes,
                                  out.as_deref())?;
            }
        }
        "info" => {
            let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
            commands::cmd_info(&dir)?;
        }
        "" | "help" | "--help" => {
            print!("{USAGE}");
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

const USAGE: &str = "\
locality-ml — locality-aware ML runtime (Chakroun et al., IDA 2020)

USAGE: locality-ml <subcommand> [--key value]...

SUBCOMMANDS
  train        Fig 5: SW-SGD sweep (optimizers x window scenarios)
                 --epochs N --cv --optimizers sgd,momentum,adam,adagrad
                 --windows 0,1,2 --dataset-n 6400 --out-csv curves.csv
  joint        Table 1: k-NN + PRW separately vs jointly
                 --data-dir data --regenerate
  fig4         Fig 4: data touched by SGD / MB-GD / SW-SGD
  interchange  Algorithms 1/2 loop interchange on the cache simulator
                 --n 256 --m 256
  cache-model  §5.1 cycle-arithmetic example (400k vs 40k cycles)
  audit        Reuse-distance audit of the paper's §3-§4 claims
  kernels      L1-native kernels: naive vs cache-blocked timings
                 --sizes 256,512,1024 --out-json BENCH_kernels.json
  parallel     Parallel macro-tile layer: 1-vs-N thread scaling curve
                 --sizes 256,512 --curve 1,2,4
                 --out-json BENCH_parallel.json
  sweep        §4.1.1 shared-distance hyperparameter sweep engine:
               naive vs shared vs split-parallel (bit-identical)
                 --dataset-n 1000 --folds 5 --ks 1,3,5,9,15
                 --bandwidth-mults 0.5,1,2,4 --curve 1,2,4
                 --out-json BENCH_sweep.json
  steal        Work-stealing scheduler on skewed CV splits: static vs
               stealing wall-clock, bit-identical results
                 --dataset-n 2000 --fold-weights 8,7,6,5,4,3,2,1,1,1,1,1
                 --curve 1,2,4 --out-json BENCH_steal.json
  dists        Distance engine: exact tiled kernel vs GEMM formulation
               over cached norms vs fused scans (parity pre-timing)
                 --train-n 4000 --queries 1000 --d 64
                 --out-json BENCH_dists.json
  pack         Packed SIMD micro-kernel: cache-tiled vs packed
               register-blocked matmul (scalar/SSE2/AVX2 dispatch;
               bit-parity with the naive oracle asserted pre-timing)
                 --sizes 256,512 --out-json BENCH_pack.json
  serve        Resident serving engine: fit once, then serve JSONL
               queries from stdin (or --socket PATH, unix) coalesced
               into micro-batches; flush on --max-batch or
               --max-wait-us, shed past --queue-cap with an explicit
               overloaded reply; replies are bit-identical to
               single-query predict
                 --train-n 4000 --max-batch 64 --max-wait-us 2000
                 --queue-cap 1024 --socket /tmp/locality-ml.sock
  serve-bench  Serving engine latency/throughput curve: saturated
               replay at several batch sizes (batch=1 baseline;
               parity vs single-query predict asserted pre-timing)
                 --train-n 4000 --queries 512 --batches 1,8,64
                 --out-json BENCH_serve.json
  convert      Write a dataset in the chunked `.lmtc` out-of-core
               layout (from --in d.lmld, or synthetic Chembl-like
               rows); re-opened and validated before reporting
                 --in data/train.lmld --out data/train.lmtc
                 --train-n 4000
  ooc          Out-of-core MCS demo: resident vs chunked `.lmtc`
               backend at each chunk size (checksummed v2 and legacy
               v1 both timed), predictions asserted bit-identical,
               working set and wall-clock reported; --verify instead
               deep-scans an existing store (header + metadata checks,
               every chunk re-read and CRC-verified)
                 --train-n 4000 --queries 256 --store data/train.lmtc
                 --chunk-sizes 256,512,2000 --out-json BENCH_ooc.json
                 --verify
  info         List compiled artifacts  [--artifacts artifacts]

Common options: --config experiment.toml --artifacts artifacts --seed N
                --threads N (parallel kernel layer; 1 = single-thread
                kernels; default LOCALITY_ML_THREADS or all cores)
                --schedule static|stealing|auto (macro-tile scheduling
                policy; identical bits either way; default
                LOCALITY_ML_SCHEDULE or auto)
                --dist-algo exact|gemm|auto (distance formulation: exact
                is the bit-stable oracle, gemm the cached-norm GEMM
                decomposition <= 1e-4 of it; default
                LOCALITY_ML_DIST_ALGO or auto)
                --chunk-rows N (feature rows per chunk for newly written
                out-of-core `.lmtc` stores; chunking never changes bits;
                default LOCALITY_ML_CHUNK_ROWS or a ~4 MiB auto size)
                --fault-spec SPEC (deterministic fault injection into
                the chunked store reader, e.g.
                `seed=1,transient=30` or `flip@2`; off unless set;
                default LOCALITY_ML_FAULT_SPEC; injected faults never
                change the bits of a successful result)
                --retry-attempts N --retry-backoff-us N (bounded retry
                for transient store faults; defaults
                LOCALITY_ML_RETRY_ATTEMPTS=3 /
                LOCALITY_ML_RETRY_BACKOFF_US=100)
                LOCALITY_ML_FORCE_SCALAR=1 pins the packed micro-kernel
                to the scalar tier (SIMD tiers are bit-identical; this
                exists for dispatch testing and perf triage)
";
